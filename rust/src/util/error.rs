//! Crate-wide error type.
//!
//! Implemented by hand on top of `std` (no `thiserror`): the crate builds
//! fully offline with zero external dependencies.

use std::fmt;

/// Errors produced by the dtans library.
#[derive(Debug)]
pub enum DtansError {
    /// Invalid codec parameters (violating the K^l >= W^o / M^l <= W^f
    /// constraints, or out-of-range fields).
    InvalidParams(String),

    /// Malformed or inconsistent matrix data.
    InvalidMatrix(String),

    /// A decoder detected a corrupt or truncated stream.
    CorruptStream(String),

    /// Container (de)serialization failure.
    Container(String),

    /// A container file does not start with the `CSRDTANS` magic — it is
    /// not one of ours (distinct from [`DtansError::Container`] so callers
    /// can tell "foreign file" from "ours but damaged").
    BadMagic {
        /// The eight bytes actually found where the magic should be.
        found: [u8; 8],
    },

    /// A container file carries a version this build does not understand
    /// — written by a future release, or by an older one whose layout
    /// this build no longer reads (the reader requires an exact version
    /// match).
    UnsupportedVersion {
        /// Version found in the file.
        found: u32,
        /// The one version this build reads.
        supported: u32,
    },

    /// A container file ended before a field could be read completely.
    Truncated(String),

    /// A container file's trailing content checksum does not match the
    /// bytes actually read — the file was modified after writing (bit
    /// rot, a torn write, deliberate tampering). Distinct from
    /// [`DtansError::Container`]: the layout parsed, but the content is
    /// not what was written.
    ChecksumMismatch {
        /// Checksum stored in the file's trailer.
        stored: u64,
        /// Checksum computed over the bytes read.
        computed: u64,
    },

    /// Mismatched dimensions in an SpMVM call.
    Dimension(String),

    /// MatrixMarket parse errors.
    MtxParse {
        /// 1-based line number of the offending input line.
        line: usize,
        /// What went wrong on that line.
        msg: String,
    },

    /// IO errors.
    Io(std::io::Error),

    /// PJRT / XLA runtime errors.
    Runtime(String),

    /// Coordinator/service errors.
    Service(String),

    /// Admission control shed the request: the bounded service queue was
    /// at capacity. Backpressure, not a bug — the caller should retry
    /// later or reduce its offered load.
    Overloaded {
        /// The configured queue depth that was full at submit time.
        queue_depth: usize,
    },

    /// The request's deadline elapsed before any kernel work started; it
    /// was rejected at dispatch, never executed.
    DeadlineExceeded,

    /// Admission control shed the request: the submitting tenant's
    /// token-bucket quota was exhausted.
    QuotaExceeded {
        /// The tenant whose bucket was empty.
        tenant: String,
    },

    /// The request was submitted to a service whose admission queue has
    /// closed (the service is shutting down). Distinct from
    /// [`DtansError::Overloaded`]: retrying cannot succeed.
    QueueClosed,

    /// Adaptive routing asked a matrix to serve a format it cannot
    /// materialize: a CSR-walk format (`csr`, `blocked_ell`) on an
    /// artifact-registered matrix with no resident CSR original, or any
    /// alternate format on an overlaid (mutated) matrix whose composite
    /// operator is the only correct execution surface. Typed — not a
    /// `Service` string — so operators can tell a bad
    /// [`RouteOverride`](crate::coordinator::adaptive::RouteOverride)
    /// pin from an execution failure. See `docs/ROUTING.md`.
    InadmissibleRoute {
        /// The matrix whose residency forbids the route.
        matrix: u64,
        /// Tag of the format that cannot be served.
        tag: &'static str,
    },
}

impl DtansError {
    /// Best-effort duplicate, preserving the variant (the coordinator
    /// fans one kernel error out to every request of a batch). `Io` is
    /// rebuilt from its kind + message since `std::io::Error` is not
    /// `Clone`.
    pub fn duplicate(&self) -> DtansError {
        match self {
            DtansError::InvalidParams(m) => DtansError::InvalidParams(m.clone()),
            DtansError::InvalidMatrix(m) => DtansError::InvalidMatrix(m.clone()),
            DtansError::CorruptStream(m) => DtansError::CorruptStream(m.clone()),
            DtansError::Container(m) => DtansError::Container(m.clone()),
            DtansError::BadMagic { found } => DtansError::BadMagic { found: *found },
            DtansError::UnsupportedVersion { found, supported } => {
                DtansError::UnsupportedVersion { found: *found, supported: *supported }
            }
            DtansError::Truncated(m) => DtansError::Truncated(m.clone()),
            DtansError::ChecksumMismatch { stored, computed } => {
                DtansError::ChecksumMismatch { stored: *stored, computed: *computed }
            }
            DtansError::Dimension(m) => DtansError::Dimension(m.clone()),
            DtansError::MtxParse { line, msg } => DtansError::MtxParse {
                line: *line,
                msg: msg.clone(),
            },
            DtansError::Io(e) => DtansError::Io(std::io::Error::new(e.kind(), e.to_string())),
            DtansError::Runtime(m) => DtansError::Runtime(m.clone()),
            DtansError::Service(m) => DtansError::Service(m.clone()),
            DtansError::Overloaded { queue_depth } => {
                DtansError::Overloaded { queue_depth: *queue_depth }
            }
            DtansError::DeadlineExceeded => DtansError::DeadlineExceeded,
            DtansError::QuotaExceeded { tenant } => {
                DtansError::QuotaExceeded { tenant: tenant.clone() }
            }
            DtansError::QueueClosed => DtansError::QueueClosed,
            DtansError::InadmissibleRoute { matrix, tag } => {
                DtansError::InadmissibleRoute { matrix: *matrix, tag }
            }
        }
    }
}

impl fmt::Display for DtansError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DtansError::InvalidParams(m) => write!(f, "invalid ANS parameters: {m}"),
            DtansError::InvalidMatrix(m) => write!(f, "invalid matrix: {m}"),
            DtansError::CorruptStream(m) => write!(f, "corrupt stream: {m}"),
            DtansError::Container(m) => write!(f, "container format error: {m}"),
            DtansError::BadMagic { found } => {
                write!(f, "container format error: bad magic {:02x?}", found)
            }
            DtansError::UnsupportedVersion { found, supported } => write!(
                f,
                "container format error: unsupported version {found} (this build reads exactly {supported})"
            ),
            DtansError::Truncated(m) => write!(f, "container format error: truncated file: {m}"),
            DtansError::ChecksumMismatch { stored, computed } => write!(
                f,
                "container format error: content checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            ),
            DtansError::Dimension(m) => write!(f, "dimension mismatch: {m}"),
            DtansError::MtxParse { line, msg } => {
                write!(f, "matrix market parse error at line {line}: {msg}")
            }
            DtansError::Io(e) => write!(f, "io error: {e}"),
            DtansError::Runtime(m) => write!(f, "runtime error: {m}"),
            DtansError::Service(m) => write!(f, "service error: {m}"),
            DtansError::Overloaded { queue_depth } => {
                write!(f, "service overloaded: admission queue full (depth {queue_depth})")
            }
            DtansError::DeadlineExceeded => {
                write!(f, "deadline exceeded before execution")
            }
            DtansError::QuotaExceeded { tenant } => {
                write!(f, "quota exhausted for tenant '{tenant}'")
            }
            DtansError::QueueClosed => {
                write!(f, "service shutting down: admission queue closed")
            }
            DtansError::InadmissibleRoute { matrix, tag } => {
                write!(
                    f,
                    "inadmissible route: matrix {matrix} cannot serve format '{tag}' \
                     (no resident CSR original, or the matrix is overlaid)"
                )
            }
        }
    }
}

impl std::error::Error for DtansError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DtansError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DtansError {
    fn from(e: std::io::Error) -> Self {
        DtansError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DtansError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive() {
        assert_eq!(
            DtansError::InvalidParams("k too small".into()).to_string(),
            "invalid ANS parameters: k too small"
        );
        assert_eq!(
            DtansError::MtxParse { line: 3, msg: "bad header".into() }.to_string(),
            "matrix market parse error at line 3: bad header"
        );
    }

    #[test]
    fn duplicate_preserves_variant_and_message() {
        let e = DtansError::CorruptStream("slice 3".into());
        let d = e.duplicate();
        assert!(matches!(d, DtansError::CorruptStream(_)));
        assert_eq!(d.to_string(), e.to_string());
        let io: DtansError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(io.duplicate(), DtansError::Io(_)));
    }

    #[test]
    fn container_variants_are_distinct_and_duplicate() {
        let m = DtansError::BadMagic { found: *b"NOTDTANS" };
        assert!(m.to_string().contains("bad magic"));
        assert!(matches!(m.duplicate(), DtansError::BadMagic { .. }));
        let v = DtansError::UnsupportedVersion { found: 9, supported: 1 };
        assert!(v.to_string().contains("unsupported version 9"));
        assert!(matches!(
            v.duplicate(),
            DtansError::UnsupportedVersion { found: 9, supported: 1 }
        ));
        let t = DtansError::Truncated("mid-array".into());
        assert!(t.to_string().contains("truncated"));
        assert!(matches!(t.duplicate(), DtansError::Truncated(_)));
        let c = DtansError::ChecksumMismatch { stored: 0xAB, computed: 0xCD };
        assert!(c.to_string().contains("checksum mismatch"));
        assert!(matches!(
            c.duplicate(),
            DtansError::ChecksumMismatch { stored: 0xAB, computed: 0xCD }
        ));
    }

    #[test]
    fn admission_variants_are_typed_and_duplicate() {
        let o = DtansError::Overloaded { queue_depth: 64 };
        assert!(o.to_string().contains("queue full (depth 64)"));
        assert!(matches!(o.duplicate(), DtansError::Overloaded { queue_depth: 64 }));
        let d = DtansError::DeadlineExceeded;
        assert!(d.to_string().contains("deadline exceeded"));
        assert!(matches!(d.duplicate(), DtansError::DeadlineExceeded));
        let q = DtansError::QuotaExceeded { tenant: "acme".into() };
        assert!(q.to_string().contains("tenant 'acme'"));
        assert!(matches!(q.duplicate(), DtansError::QuotaExceeded { .. }));
        let c = DtansError::QueueClosed;
        assert!(c.to_string().contains("queue closed"));
        assert!(matches!(c.duplicate(), DtansError::QueueClosed));
    }

    #[test]
    fn inadmissible_route_is_typed_and_duplicates() {
        let e = DtansError::InadmissibleRoute { matrix: 42, tag: "csr" };
        assert!(e.to_string().contains("matrix 42"));
        assert!(e.to_string().contains("format 'csr'"));
        assert!(matches!(
            e.duplicate(),
            DtansError::InadmissibleRoute { matrix: 42, tag: "csr" }
        ));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: DtansError = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
