//! PJRT client wrapper: load HLO-text artifacts, compile them once, execute
//! with typed argument vectors. Adapted from /opt/xla-example/load_hlo.rs.

use crate::util::error::{DtansError, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A typed argument for artifact execution.
#[derive(Debug, Clone)]
pub enum Arg {
    /// i32 tensor (row-major; dims given separately for >1-D).
    I32(Vec<i32>),
    /// f32 tensor.
    F32(Vec<f32>),
    /// f32 matrix (row-major).
    F32Mat(Vec<f32>, usize, usize),
}

/// PJRT CPU client + compiled-executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime").field("dir", &self.dir).finish()
    }
}

fn xerr(e: xla::Error) -> DtansError {
    DtansError::Runtime(e.to_string())
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(PjrtRuntime {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact `<name>.hlo.txt`.
    fn executable(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(DtansError::Runtime(format!(
                "artifact {} not found (run `make artifacts`)",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 path"),
        )
        .map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given arguments; returns the flattened
    /// f32 result (entries are lowered with `return_tuple=True`, so the
    /// output is a 1-tuple of one f32 tensor).
    pub fn execute_f32(&self, name: &str, args: &[Arg]) -> Result<Vec<f32>> {
        self.executable(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).expect("just compiled");
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| -> Result<xla::Literal> {
                Ok(match a {
                    Arg::I32(v) => xla::Literal::vec1(v),
                    Arg::F32(v) => xla::Literal::vec1(v),
                    Arg::F32Mat(v, r, c) => xla::Literal::vec1(v)
                        .reshape(&[*r as i64, *c as i64])
                        .map_err(xerr)?,
                })
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let lit = result[0][0].to_literal_sync().map_err(xerr)?;
        let out = lit.to_tuple1().map_err(xerr)?;
        out.to_vec::<f32>().map_err(xerr)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/runtime_artifacts.rs (they
    // need `make artifacts` to have run); here we only check error paths
    // that do not require artifacts.
    use super::*;

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = PjrtRuntime::new(Path::new("/nonexistent-dir")).unwrap();
        let err = rt.execute_f32("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("not found"), "{err}");
    }
}
