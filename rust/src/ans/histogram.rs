//! Normalizing a symbol histogram to `K` table slots with per-symbol cap
//! `M` — the approximation `P ≈ P'` of §III-D/§IV-C, chosen to minimize
//! cross entropy `H(P, P')`.

use crate::util::error::{DtansError, Result};

/// Normalize raw counts to multiplicities summing exactly to `k`, with
/// `1 ≤ mult[i] ≤ m_cap` for every symbol with `counts[i] > 0`.
///
/// Starts from the rounded proportional assignment and then repairs the sum
/// by greedy steepest-descent on cross entropy: each unit moved to/from the
/// symbol where the change costs least. This is the standard
/// fast-normalization scheme for tANS tables, extended with the paper's
/// multiplicity cap `M` (§IV-C).
///
/// Requirements: `counts` non-empty, every count > 0 (filter zeros before
/// calling), `counts.len() ≤ k` and `counts.len() * m_cap ≥ k` (otherwise
/// no assignment exists — the caller pads/duplicates symbols, see
/// `format::symbolize`).
pub fn normalize_counts(counts: &[u64], k: u32, m_cap: u32) -> Result<Vec<u32>> {
    let n = counts.len();
    let k = k as u64;
    let m_cap = m_cap as u64;
    if n == 0 {
        return Err(DtansError::InvalidParams("empty histogram".into()));
    }
    if counts.iter().any(|&c| c == 0) {
        return Err(DtansError::InvalidParams("zero count in histogram".into()));
    }
    if (n as u64) > k {
        return Err(DtansError::InvalidParams(format!(
            "{n} symbols exceed {k} slots"
        )));
    }
    if (n as u64) * m_cap < k {
        return Err(DtansError::InvalidParams(format!(
            "{n} symbols with cap {m_cap} cannot fill {k} slots"
        )));
    }

    let total: u64 = counts.iter().sum();
    let mut mult: Vec<u64> = counts
        .iter()
        .map(|&c| {
            let ideal = (c as f64) * (k as f64) / (total as f64);
            (ideal.round() as u64).clamp(1, m_cap)
        })
        .collect();
    let mut sum: u64 = mult.iter().sum();

    // Cost of multiplicity q for count c is -c*log2(q/K); moving one unit
    // changes the cost by c*log2(q/(q±1)). Repair the sum greedily. The
    // histogram is at most K entries, so O(n) scans per unit are fine for
    // the build path (encode-time only).
    while sum != k {
        if sum > k {
            // Decrement where the entropy penalty is smallest.
            let mut best = usize::MAX;
            let mut best_cost = f64::INFINITY;
            for i in 0..n {
                if mult[i] > 1 {
                    let c = counts[i] as f64;
                    let q = mult[i] as f64;
                    let cost = c * (q / (q - 1.0)).log2();
                    if cost < best_cost {
                        best_cost = cost;
                        best = i;
                    }
                }
            }
            debug_assert!(best != usize::MAX);
            mult[best] -= 1;
            sum -= 1;
        } else {
            // Increment where the entropy gain is largest.
            let mut best = usize::MAX;
            let mut best_gain = f64::NEG_INFINITY;
            for i in 0..n {
                if mult[i] < m_cap {
                    let c = counts[i] as f64;
                    let q = mult[i] as f64;
                    let gain = c * ((q + 1.0) / q).log2();
                    if gain > best_gain {
                        best_gain = gain;
                        best = i;
                    }
                }
            }
            debug_assert!(best != usize::MAX);
            mult[best] += 1;
            sum += 1;
        }
    }
    Ok(mult.into_iter().map(|x| x as u32).collect())
}

/// Cross entropy H(P, P') in bits/symbol for counts vs multiplicities
/// normalized to `k` slots — Eq. (2) with `P'(i) = mult[i]/K`.
pub fn cross_entropy_bits(counts: &[u64], mult: &[u32], k: u32) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    counts
        .iter()
        .zip(mult)
        .map(|(&c, &q)| {
            let p = c as f64 / total as f64;
            let pq = q as f64 / k as f64;
            -p * pq.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::stats::entropy_of_counts;

    #[test]
    fn paper_example_normalization() {
        // §III-D: counts (a,1),(b,5),(c,4), K=8 -> P' = (1,4,3)/8 is the
        // cross-entropy-optimal assignment (H' ~ 1.366 < 1.5 of (2,4,2)).
        let mult = normalize_counts(&[1, 5, 4], 8, 8).unwrap();
        assert_eq!(mult, vec![1, 4, 3]);
    }

    #[test]
    fn sums_to_k_and_caps() {
        let counts = vec![1000, 100, 10, 1];
        let mult = normalize_counts(&counts, 64, 16).unwrap();
        assert_eq!(mult.iter().sum::<u32>(), 64);
        assert!(mult.iter().all(|&q| (1..=16).contains(&q)));
        // Dominant symbol hits the cap.
        assert_eq!(mult[0], 16);
    }

    #[test]
    fn uniform_counts_uniform_slots() {
        let mult = normalize_counts(&[7, 7, 7, 7], 16, 8).unwrap();
        assert_eq!(mult, vec![4, 4, 4, 4]);
    }

    #[test]
    fn near_entropy_for_large_tables() {
        // With a large table and no binding cap, H(P,P') ~ H(P).
        let counts: Vec<u64> = (1..=32).map(|i| i * i).collect();
        let mult = normalize_counts(&counts, 4096, 4096).unwrap();
        let h = entropy_of_counts(counts.clone());
        let hx = cross_entropy_bits(&counts, &mult, 4096);
        assert!(hx >= h - 1e-9);
        assert!(hx < h + 0.01, "H={h} H'={hx}");
    }

    #[test]
    fn infeasible_rejected() {
        assert!(normalize_counts(&[1; 10], 8, 8).is_err()); // too many symbols
        assert!(normalize_counts(&[1, 1], 64, 8).is_err()); // cap too low
        assert!(normalize_counts(&[], 8, 8).is_err());
        assert!(normalize_counts(&[0, 3], 8, 8).is_err());
    }

    #[test]
    fn single_symbol_fills_table() {
        let mult = normalize_counts(&[42], 8, 8).unwrap();
        assert_eq!(mult, vec![8]);
    }
}
