//! Deterministic fault injection for serialized `.dtans` containers and
//! the store's on-disk cache.
//!
//! Two tools:
//!
//! * [`corrupt`] — a seeded byte-corruption engine over a serialized
//!   container buffer. Every [`FaultMode`] is deterministic in
//!   `(bytes, mode, seed)` and guaranteed to change the buffer, so a test
//!   that asserts "corrupted input must fail to load" can never pass
//!   vacuously on an unchanged buffer. The length-prefix modes use
//!   [`length_prefix_offsets`], a layout walker that locates every array
//!   length in the container format, so "inflate a length prefix" hits a
//!   real length prefix instead of a random byte that happens to decode
//!   as one.
//! * [`FailingDir`] — a cache-root shim for
//!   [`StoreConfig::cache_dir`](crate::store::StoreConfig::cache_dir)
//!   that opens deterministic *failure windows*: [`FailingDir::break_writes`]
//!   makes every artifact persist fail (the root becomes a regular file,
//!   so `create_dir_all` under it errors) until
//!   [`FailingDir::restore_writes`]; [`FailingDir::corrupt_artifacts`]
//!   damages persisted artifacts in place so cold loads fail, and
//!   [`FailingDir::snapshot`]/[`FailingDir::restore`] bracket that window
//!   so a test can prove the failure did not poison any retry path.
//!
//! These replace the ad-hoc corruption loops that lived inside
//! `format::serialize`'s unit tests and give `tests/fault_injection.rs`
//! one engine for every error path: serializer, artifact cache, loader,
//! and service.

use crate::util::error::Result;
use crate::util::rng::Xoshiro256;
use std::path::{Path, PathBuf};

/// Serialized-container header bytes before the first array length
/// prefix: magic (8) + version (4) + six `AnsParams` fields (24) +
/// precision (4) + delta flag (4) + nrows/ncols/nnz (24).
const HEADER_BYTES: usize = 8 + 4 + 24 + 4 + 4 + 24;

/// One way to damage a serialized container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Flip a single bit at a seeded byte offset.
    BitFlip,
    /// Cut the buffer at a seeded offset (always strictly shorter).
    Truncate,
    /// Overwrite a seeded array length prefix with an inflated value
    /// (alternating between a plausible small inflation, which runs the
    /// reader off the end of the data, and an implausibly huge one, which
    /// must be rejected before any allocation).
    InflateLength,
    /// Swap the contents of two *different* array length prefixes —
    /// the cross-array corruption that only mutual-consistency
    /// validation can catch.
    SwapLengths,
    /// Zero a seeded 16-byte span.
    ZeroSpan,
}

/// Every [`FaultMode`], for exhaustive sweeps.
pub const ALL_FAULT_MODES: [FaultMode; 5] = [
    FaultMode::BitFlip,
    FaultMode::Truncate,
    FaultMode::InflateLength,
    FaultMode::SwapLengths,
    FaultMode::ZeroSpan,
];

/// Byte offsets of every array length prefix in a serialized container,
/// in on-disk order, found by walking the layout with the lengths read
/// from the buffer itself. Stops early (returning the prefixes found so
/// far) if the buffer is too short to keep walking.
pub fn length_prefix_offsets(bytes: &[u8]) -> Vec<usize> {
    let mut offs = Vec::new();
    let mut pos = HEADER_BYTES;
    let walk = |elem_bytes: usize, pos: &mut usize, offs: &mut Vec<usize>| -> bool {
        if *pos + 8 > bytes.len() {
            return false;
        }
        let len =
            u64::from_le_bytes(bytes[*pos..*pos + 8].try_into().expect("8 bytes")) as usize;
        offs.push(*pos);
        match len
            .checked_mul(elem_bytes)
            .and_then(|data| pos.checked_add(8 + data))
        {
            Some(next) if next <= bytes.len() => {
                *pos = next;
                true
            }
            _ => false,
        }
    };
    // Two symbol domains: u64 payloads, 1-byte escape flags, u32
    // multiplicities, then a bare u32 (escape payload bits).
    for _ in 0..2 {
        for elem in [8usize, 1, 4] {
            if !walk(elem, &mut pos, &mut offs) {
                return offs;
            }
        }
        pos += 4; // escape_payload_bits
    }
    // row_nnz, slice_offsets, stream, delta_escapes (u32); value_escapes
    // (u64); delta/value escape offsets (u32).
    for elem in [4usize, 4, 4, 4, 8, 4, 4] {
        if !walk(elem, &mut pos, &mut offs) {
            return offs;
        }
    }
    offs
}

fn read_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().expect("8 bytes"))
}

fn write_u64(bytes: &mut [u8], off: usize, v: u64) {
    bytes[off..off + 8].copy_from_slice(&v.to_le_bytes());
}

/// Deterministically corrupt `bytes` with `mode` at seeded offsets.
/// The result always differs from the input (modes that could no-op fall
/// back to a bit flip). Panics only if `bytes` is empty.
pub fn corrupt(bytes: &[u8], mode: FaultMode, seed: u64) -> Vec<u8> {
    assert!(!bytes.is_empty(), "cannot corrupt an empty buffer");
    // Mix the mode into the stream so one seed drives distinct offsets
    // per mode.
    let mut rng = Xoshiro256::seeded(seed ^ (mode as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let mut out = bytes.to_vec();
    match mode {
        FaultMode::BitFlip => flip_bit(&mut out, &mut rng),
        FaultMode::Truncate => {
            let cut = rng.below_usize(out.len());
            out.truncate(cut);
        }
        FaultMode::InflateLength => {
            let offs = length_prefix_offsets(bytes);
            if offs.is_empty() {
                flip_bit(&mut out, &mut rng);
            } else {
                let off = offs[rng.below_usize(offs.len())];
                let cur = read_u64(&out, off);
                let inflated = if rng.chance(0.5) {
                    // Plausible: the reader runs out of data mid-array.
                    cur + 1 + rng.below(1 << 16)
                } else {
                    // Implausible: must be rejected before preallocation.
                    (1 << 40) + 1 + rng.below(1 << 20)
                };
                write_u64(&mut out, off, inflated);
            }
        }
        FaultMode::SwapLengths => {
            let offs = length_prefix_offsets(bytes);
            // Pick two prefixes with different stored values so the swap
            // is guaranteed to change the buffer.
            let mut pairs = Vec::new();
            for (i, &a) in offs.iter().enumerate() {
                for &b in &offs[i + 1..] {
                    if read_u64(bytes, a) != read_u64(bytes, b) {
                        pairs.push((a, b));
                    }
                }
            }
            if pairs.is_empty() {
                flip_bit(&mut out, &mut rng);
            } else {
                let (a, b) = pairs[rng.below_usize(pairs.len())];
                let (va, vb) = (read_u64(&out, a), read_u64(&out, b));
                write_u64(&mut out, a, vb);
                write_u64(&mut out, b, va);
            }
        }
        FaultMode::ZeroSpan => {
            let off = rng.below_usize(out.len());
            let end = (off + 16).min(out.len());
            if out[off..end].iter().all(|&b| b == 0) {
                out[off] = 0xFF; // span already zero: still change it
            } else {
                out[off..end].iter_mut().for_each(|b| *b = 0);
            }
        }
    }
    debug_assert_ne!(out, bytes, "corruption must change the buffer");
    out
}

fn flip_bit(out: &mut [u8], rng: &mut Xoshiro256) {
    let off = rng.below_usize(out.len());
    out[off] ^= 1 << rng.below(8);
}

/// Corrupt a file on disk in place (read, [`corrupt`], rewrite).
pub fn corrupt_file(path: &Path, mode: FaultMode, seed: u64) -> Result<()> {
    let bytes = std::fs::read(path)?;
    std::fs::write(path, corrupt(&bytes, mode, seed))?;
    Ok(())
}

/// A managed cache-root directory whose writes and reads can be made to
/// fail in deterministic windows — the shim behind the
/// [`store`](crate::store) error-path tests. See the
/// [module docs](self) for the failure model. The directory is removed on
/// drop.
pub struct FailingDir {
    root: PathBuf,
}

impl FailingDir {
    /// Create a fresh managed directory (unique per `tag` + process).
    pub fn new(tag: &str) -> Result<FailingDir> {
        let root = std::env::temp_dir()
            .join(format!("dtans_testkit_faildir_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_file(&root);
        std::fs::create_dir_all(&root)?;
        Ok(FailingDir { root })
    }

    /// The root path (pass as
    /// [`StoreConfig::cache_dir`](crate::store::StoreConfig::cache_dir)).
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Open a write-failure window: the root is replaced by a regular
    /// file, so every artifact persist under it fails (`create_dir_all`
    /// on a path with a non-directory component errors for any user, root
    /// included). **Deletes anything currently inside the root.**
    pub fn break_writes(&self) -> Result<()> {
        std::fs::remove_dir_all(&self.root)?;
        std::fs::write(&self.root, b"testkit failing dir")?;
        Ok(())
    }

    /// Close the write-failure window: the root becomes an (empty)
    /// directory again.
    pub fn restore_writes(&self) -> Result<()> {
        let _ = std::fs::remove_file(&self.root);
        std::fs::create_dir_all(&self.root)?;
        Ok(())
    }

    /// All persisted `.dtans` artifacts under the root, sorted for
    /// determinism.
    pub fn artifacts(&self) -> Vec<PathBuf> {
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let Ok(entries) = std::fs::read_dir(&dir) else { continue };
            for e in entries.flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().is_some_and(|x| x == "dtans") {
                    out.push(p);
                }
            }
        }
        out.sort();
        out
    }

    /// Corrupt every persisted artifact in place with `mode`; returns how
    /// many files were damaged. Subsequent cold loads from this cache
    /// must surface typed errors.
    pub fn corrupt_artifacts(&self, mode: FaultMode, seed: u64) -> Result<usize> {
        let files = self.artifacts();
        for (i, f) in files.iter().enumerate() {
            corrupt_file(f, mode, seed ^ i as u64)?;
        }
        Ok(files.len())
    }

    /// Snapshot every artifact's bytes (pair with [`FailingDir::restore`]
    /// to close a read-failure window).
    pub fn snapshot(&self) -> Result<Vec<(PathBuf, Vec<u8>)>> {
        let mut out = Vec::new();
        for f in self.artifacts() {
            let bytes = std::fs::read(&f)?;
            out.push((f, bytes));
        }
        Ok(out)
    }

    /// Restore artifacts from a [`FailingDir::snapshot`].
    pub fn restore(&self, snapshot: &[(PathBuf, Vec<u8>)]) -> Result<()> {
        for (path, bytes) in snapshot {
            std::fs::write(path, bytes)?;
        }
        Ok(())
    }
}

impl Drop for FailingDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
        let _ = std::fs::remove_file(&self.root);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
    use crate::format::serialize;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};

    fn sample_bytes() -> Vec<u8> {
        let mut m = banded(120, 3);
        assign_values(&mut m, ValueDist::Quantized(16), &mut Xoshiro256::seeded(5));
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let mut buf = Vec::new();
        serialize::write_to(&enc, &mut buf).unwrap();
        buf
    }

    #[test]
    fn walker_finds_all_thirteen_length_prefixes() {
        let buf = sample_bytes();
        let offs = length_prefix_offsets(&buf);
        // 2 domains x 3 arrays + 7 top-level arrays.
        assert_eq!(offs.len(), 13, "{offs:?}");
        assert_eq!(offs[0], HEADER_BYTES);
        // Each stored length must be plausible for the buffer size.
        for &o in &offs {
            assert!(read_u64(&buf, o) < buf.len() as u64);
        }
        // Offsets strictly ascend.
        assert!(offs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn walker_stops_cleanly_on_short_buffers() {
        let buf = sample_bytes();
        for cut in [0, 10, HEADER_BYTES, HEADER_BYTES + 4, buf.len() / 2] {
            let offs = length_prefix_offsets(&buf[..cut]);
            assert!(offs.len() <= 13);
        }
    }

    #[test]
    fn corruption_is_deterministic_and_always_changes_the_buffer() {
        let buf = sample_bytes();
        for mode in ALL_FAULT_MODES {
            for seed in 0..20u64 {
                let a = corrupt(&buf, mode, seed);
                let b = corrupt(&buf, mode, seed);
                assert_eq!(a, b, "{mode:?} seed {seed} not deterministic");
                assert_ne!(a, buf, "{mode:?} seed {seed} did not change the buffer");
            }
        }
    }

    #[test]
    fn truncate_is_strictly_shorter_and_swap_hits_two_prefixes() {
        let buf = sample_bytes();
        for seed in 0..10u64 {
            assert!(corrupt(&buf, FaultMode::Truncate, seed).len() < buf.len());
            let swapped = corrupt(&buf, FaultMode::SwapLengths, seed);
            assert_eq!(swapped.len(), buf.len());
            let changed: Vec<usize> =
                (0..buf.len()).filter(|&i| swapped[i] != buf[i]).collect();
            // All changed bytes lie inside length-prefix fields.
            let offs = length_prefix_offsets(&buf);
            for i in changed {
                assert!(
                    offs.iter().any(|&o| (o..o + 8).contains(&i)),
                    "byte {i} outside any length prefix"
                );
            }
        }
    }

    #[test]
    fn failing_dir_breaks_and_restores_writes() {
        let dir = FailingDir::new("unit_breaks").unwrap();
        let probe = dir.root().join("aa").join("probe.dtans");
        std::fs::create_dir_all(probe.parent().unwrap()).unwrap();
        std::fs::write(&probe, b"x").unwrap();
        assert_eq!(dir.artifacts().len(), 1);
        dir.break_writes().unwrap();
        assert!(std::fs::create_dir_all(probe.parent().unwrap()).is_err());
        assert!(dir.artifacts().is_empty());
        dir.restore_writes().unwrap();
        std::fs::create_dir_all(probe.parent().unwrap()).unwrap();
        std::fs::write(&probe, b"y").unwrap();
        assert_eq!(dir.artifacts().len(), 1);
    }

    #[test]
    fn snapshot_restore_roundtrips_artifact_bytes() {
        let dir = FailingDir::new("unit_snapshot").unwrap();
        let f = dir.root().join("bb").join("m.dtans");
        std::fs::create_dir_all(f.parent().unwrap()).unwrap();
        std::fs::write(&f, sample_bytes()).unwrap();
        let snap = dir.snapshot().unwrap();
        assert_eq!(dir.corrupt_artifacts(FaultMode::Truncate, 1).unwrap(), 1);
        assert_ne!(std::fs::read(&f).unwrap(), snap[0].1);
        dir.restore(&snap).unwrap();
        assert_eq!(std::fs::read(&f).unwrap(), snap[0].1);
    }
}
