//! Property tests (custom propcheck harness) for the parallel SpMV
//! engine, pinning its central contract: for every partition count in
//! 1..=16 and every supported format, the parallel engine's output is
//! **bit-identical** to the serial kernel's — not merely numerically
//! close. This holds because the nnz-balanced partitioner assigns every
//! row (or 32-row slice) to exactly one contiguous block, and each block
//! runs the serial kernel's arithmetic unchanged.
//!
//! Also pinned: the partitioner's structural invariants (coverage,
//! disjointness, cost conservation, balance bound) over arbitrary cost
//! prefixes.

use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::coo::Coo;
use dtans::matrix::csr::Csr;
use dtans::matrix::gen::structured::{banded, powerlaw_rows, stencil2d5};
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::matrix::Sell;
use dtans::spmv::engine::{partition_prefix, ParStrategy, SpmvEngine};
use dtans::spmv::operator::DtansOperator;
use dtans::spmv::{spmv_csr, spmv_csr_dtans, spmv_sell, DenseMat};
use dtans::util::propcheck::{check, Ctx};
use dtans::util::rng::Xoshiro256;

/// Random sparse matrix mixing graph and structured families, with value
/// palettes that exercise both the dictionary and escape paths.
fn random_csr(ctx: &mut Ctx) -> Csr {
    let n = 1 + ctx.rng.below_usize(ctx.size.max(1));
    let mut m = match ctx.rng.below(4) {
        0 => gen_graph_csr(GraphModel::ErdosRenyi, n.max(4), 4.0, &mut ctx.rng),
        1 => powerlaw_rows(n.max(4), 5.0, 1.1, &mut ctx.rng),
        2 => banded(n.max(2), 1 + ctx.rng.below_usize(4)),
        _ => {
            let side = 2 + ctx.rng.below_usize((n as f64).sqrt() as usize + 2);
            stencil2d5(side, side)
        }
    };
    let dist = match ctx.rng.below(3) {
        0 => ValueDist::FewDistinct(6),
        1 => ValueDist::Gaussian,
        _ => ValueDist::Quantized(64),
    };
    assign_values(&mut m, dist, &mut ctx.rng);
    m
}

fn random_x(ctx: &mut Ctx, n: usize) -> Vec<f64> {
    (0..n).map(|_| ctx.rng.next_f64() - 0.5).collect()
}

#[test]
fn prop_partition_invariants() {
    check("partition-invariants", 80, 200, |ctx: &mut Ctx| {
        // Random unit costs, frequently zero (empty rows) and occasionally
        // huge (pathological skew).
        let units = ctx.rng.below_usize(ctx.size + 1);
        let mut prefix = Vec::with_capacity(units + 1);
        prefix.push(0usize);
        for _ in 0..units {
            let cost = match ctx.rng.below(4) {
                0 => 0,
                1 => ctx.rng.below_usize(4),
                2 => ctx.rng.below_usize(100),
                _ => ctx.rng.below_usize(10_000),
            };
            let last = *prefix.last().unwrap();
            prefix.push(last + cost);
        }
        let total = *prefix.last().unwrap();
        let max_unit = prefix.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0);
        for parts in 1..=16 {
            let blocks = partition_prefix(&prefix, parts);
            if units == 0 {
                if !blocks.is_empty() {
                    return Err("blocks for zero units".into());
                }
                continue;
            }
            let eff = parts.min(units);
            if blocks.is_empty() || blocks.len() > eff {
                return Err(format!("bad block count {} (parts {parts})", blocks.len()));
            }
            if blocks[0].start != 0 || blocks.last().unwrap().end != units {
                return Err("blocks do not cover all units".into());
            }
            let mut expect_start = 0;
            let mut cost_sum = 0;
            for b in &blocks {
                if b.start != expect_start {
                    return Err(format!("gap/overlap at block {b:?}"));
                }
                if b.end <= b.start {
                    return Err(format!("empty block {b:?}"));
                }
                if b.cost != prefix[b.end] - prefix[b.start] {
                    return Err(format!("wrong cost in {b:?}"));
                }
                if b.cost > total.div_ceil(eff) + max_unit {
                    return Err(format!(
                        "unbalanced block {b:?}: cost {} > {}/{} + {max_unit}",
                        b.cost, total, eff
                    ));
                }
                expect_start = b.end;
                cost_sum += b.cost;
            }
            if cost_sum != total {
                return Err("block costs do not sum to total".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_csr_bit_identical_across_partition_counts() {
    check("engine-csr-bitident", 20, 150, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let x = random_x(ctx, m.ncols);
        // Nonzero initial y exercises the += contract.
        let y0: Vec<f64> = (0..m.nrows).map(|i| (i as f64) * 0.125).collect();
        let mut want = y0.clone();
        spmv_csr(&m, &x, &mut want).map_err(|e| e.to_string())?;
        for parts in 1..=16 {
            let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
            let mut got = y0.clone();
            engine.run(&m, &x, &mut got).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("CSR mismatch at parts={parts}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_dtans_bit_identical_across_partition_counts() {
    check("engine-dtans-bitident", 12, 150, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let opts = if ctx.rng.chance(0.5) {
            EncodeOptions::default()
        } else {
            EncodeOptions {
                params: dtans::ans::AnsParams::KERNEL,
                ..Default::default()
            }
        };
        let enc = CsrDtans::encode(&m, &opts).map_err(|e| e.to_string())?;
        let x = random_x(ctx, m.ncols);
        let y0: Vec<f64> = (0..m.nrows).map(|i| (i as f64) * -0.25).collect();
        let mut want = y0.clone();
        spmv_csr_dtans(&enc, &x, &mut want).map_err(|e| e.to_string())?;
        let op = DtansOperator::new(enc);
        for parts in 1..=16 {
            let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
            let mut got = y0.clone();
            engine.run(&op, &x, &mut got).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("CSR-dtANS mismatch at parts={parts}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_sell_bit_identical() {
    check("engine-sell-bitident", 12, 120, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let sell = Sell::from_csr(&m, 32);
        let x = random_x(ctx, m.ncols);
        let mut want = vec![0.0; m.nrows];
        spmv_sell(&sell, &x, &mut want).map_err(|e| e.to_string())?;
        for parts in [1usize, 2, 5, 16] {
            let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
            let mut got = vec![0.0; m.nrows];
            engine.run(&sell, &x, &mut got).map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("SELL mismatch at parts={parts}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spmm_bit_identical_to_repeated_spmv() {
    check("engine-spmm-bitident", 12, 100, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).map_err(|e| e.to_string())?;
        let k = 1 + ctx.rng.below_usize(6);
        let cols: Vec<Vec<f64>> = (0..k).map(|_| random_x(ctx, m.ncols)).collect();
        let xs = DenseMat::from_cols(m.ncols, &cols).map_err(|e| e.to_string())?;
        let parts = 1 + ctx.rng.below_usize(16);
        let engine = SpmvEngine::new(ParStrategy::Fixed(parts));

        let op = DtansOperator::new(enc.clone());
        let ys = engine.run_multi(&m, &xs).map_err(|e| e.to_string())?.into_cols();
        let yd = engine.run_multi(&op, &xs).map_err(|e| e.to_string())?.into_cols();
        for (j, x) in cols.iter().enumerate() {
            let mut want = vec![0.0; m.nrows];
            spmv_csr(&m, x, &mut want).map_err(|e| e.to_string())?;
            if ys[j] != want {
                return Err(format!("csr run_multi rhs {j} mismatch (parts {parts})"));
            }
            let mut want_d = vec![0.0; m.nrows];
            spmv_csr_dtans(&enc, x, &mut want_d).map_err(|e| e.to_string())?;
            if yd[j] != want_d {
                return Err(format!("dtans run_multi rhs {j} mismatch (parts {parts})"));
            }
        }
        Ok(())
    });
}

#[test]
fn engine_handles_empty_rows_and_tail_slices() {
    // Deterministic edge cases: empty matrix, single nonzero in the last
    // slice, all-empty rows — across several partition counts.
    let mut cases: Vec<Csr> = vec![Csr::new(40, 40), Csr::new(0, 0)];
    let mut coo = Coo::new(65, 65);
    coo.push(64, 64, 2.0);
    cases.push(Csr::from_coo(&coo));
    for m in &cases {
        let enc = CsrDtans::encode(m, &EncodeOptions::default()).unwrap();
        let x = vec![1.0; m.ncols];
        let mut want = vec![0.5; m.nrows];
        spmv_csr_dtans(&enc, &x, &mut want).unwrap();
        let op = DtansOperator::new(enc);
        for parts in [1usize, 3, 16] {
            let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
            let mut got = vec![0.5; m.nrows];
            engine.run(&op, &x, &mut got).unwrap();
            assert_eq!(got, want);
            let mut got_csr = vec![0.5; m.nrows];
            engine.run(m, &x, &mut got_csr).unwrap();
            let mut want_csr = vec![0.5; m.nrows];
            spmv_csr(m, &x, &mut want_csr).unwrap();
            assert_eq!(got_csr, want_csr);
        }
    }
}

#[test]
fn engine_big_matrix_parallel_speedpath_is_exact() {
    // A matrix comfortably above the Auto threshold: the parallel path
    // actually engages and must still be bit-identical.
    let mut rng = Xoshiro256::seeded(42);
    let mut m = banded(30_000, 3);
    assign_values(&mut m, ValueDist::FewDistinct(12), &mut rng);
    let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
    let mut want = vec![0.0; m.nrows];
    spmv_csr_dtans(&enc, &x, &mut want).unwrap();
    let engine = SpmvEngine::auto();
    let mut got = vec![0.0; m.nrows];
    engine.run(&DtansOperator::new(enc), &x, &mut got).unwrap();
    assert_eq!(got, want);
}
