//! Evaluation harness: the synthetic corpus and the drivers that
//! regenerate every table and figure of the paper's §V (see the
//! experiment index in DESIGN.md).

pub mod corpus;
pub mod experiments;
pub mod report;

pub use corpus::{build_corpus, CorpusEntry, CorpusScale};
pub use experiments::{ablate, fig4, fig6, fig9, runtime_experiment, tab1, ExperimentOutput};
