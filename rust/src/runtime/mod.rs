//! Runtime layer: load the AOT-compiled JAX/Pallas artifacts (HLO text)
//! via PJRT and execute them from Rust — python never runs on this path.

pub mod bundle;
pub mod client;
pub mod manifest;

pub use client::{Arg, PjrtRuntime};
pub use manifest::{Bucket, Manifest};

use crate::format::csr_dtans::CsrDtans;
use crate::util::error::{DtansError, Result};
use std::path::Path;

/// High-level artifact runtime: manifest + PJRT client + bucket selection.
#[derive(Debug)]
pub struct Runtime {
    /// Parsed manifest.
    pub manifest: Manifest,
    client: PjrtRuntime,
}

impl Runtime {
    /// Open an artifact directory (expects `manifest.txt` + `*.hlo.txt`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        Ok(Runtime {
            manifest: Manifest::load(dir)?,
            client: PjrtRuntime::new(dir)?,
        })
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        self.client.platform()
    }

    /// `y = A·x + y_in` through the AOT-compiled fused decode+SpMVM kernel.
    /// The matrix must be KERNEL/F32-encoded; the smallest fitting bucket
    /// is selected automatically.
    pub fn spmv_dtans(&self, m: &CsrDtans, x: &[f64], y_in: &[f64]) -> Result<Vec<f32>> {
        bundle::check_kernel_compatible(m)?;
        let max_seg = bundle::max_segments(m);
        let (bname, bucket) = self
            .manifest
            .pick_bucket(
                m.nrows,
                m.ncols,
                m.stream.len(),
                m.delta_escapes.len().max(m.value_escapes.len()),
                max_seg,
            )
            .ok_or_else(|| {
                DtansError::Runtime(format!(
                    "no bucket fits matrix {}x{} ({} words, {} segs)",
                    m.nrows,
                    m.ncols,
                    m.stream.len(),
                    max_seg
                ))
            })?;
        let args = bundle::build_args(m, bucket, x, y_in)?;
        let name = format!("spmv_dtans_{bname}");
        let y = self.client.execute_f32(&name, &args)?;
        Ok(y[..m.nrows].to_vec())
    }

    /// `y = A·x + y_in` through the jnp scatter-add CSR artifact (baseline
    /// on the PJRT path).
    pub fn spmv_csr_jnp(
        &self,
        m: &crate::matrix::Csr,
        x: &[f64],
        y_in: &[f64],
    ) -> Result<Vec<f32>> {
        let (bname, bucket) = self
            .manifest
            .pick_bucket(m.nrows, m.ncols, 0, 0, 0)
            .filter(|(_, b)| b.nnz >= m.nnz())
            .ok_or_else(|| DtansError::Runtime("no bucket fits CSR matrix".into()))?;
        let mut row_ids = vec![bucket.nrows as i32; bucket.nnz]; // dead target
        let mut cols = vec![0i32; bucket.nnz];
        let mut vals = vec![0.0f32; bucket.nnz];
        let mut k = 0;
        for r in 0..m.nrows {
            for i in m.row_ptr[r]..m.row_ptr[r + 1] {
                row_ids[k] = r as i32;
                cols[k] = m.cols[i] as i32;
                vals[k] = m.vals[i] as f32;
                k += 1;
            }
        }
        let mut xp: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        xp.resize(bucket.ncols, 0.0);
        let mut yp: Vec<f32> = y_in.iter().map(|&v| v as f32).collect();
        yp.resize(bucket.nrows, 0.0);
        let y = self.client.execute_f32(
            &format!("spmv_csr_jnp_{bname}"),
            &[
                Arg::I32(row_ids),
                Arg::I32(cols),
                Arg::F32(vals),
                Arg::F32(xp),
                Arg::F32(yp),
            ],
        )?;
        Ok(y[..m.nrows].to_vec())
    }

    /// Dense `y = A·x + y_in` artifact (reference / sanity path).
    pub fn dense_matvec(
        &self,
        a: &[f32],
        nrows: usize,
        ncols: usize,
        x: &[f32],
        y_in: &[f32],
    ) -> Result<Vec<f32>> {
        let (bname, bucket) = self
            .manifest
            .pick_bucket(nrows, ncols, 0, 0, 0)
            .ok_or_else(|| DtansError::Runtime("no bucket fits dense matrix".into()))?;
        let mut ap = vec![0.0f32; bucket.nrows * bucket.ncols];
        for r in 0..nrows {
            ap[r * bucket.ncols..r * bucket.ncols + ncols]
                .copy_from_slice(&a[r * ncols..(r + 1) * ncols]);
        }
        let mut xp = x.to_vec();
        xp.resize(bucket.ncols, 0.0);
        let mut yp = y_in.to_vec();
        yp.resize(bucket.nrows, 0.0);
        let y = self.client.execute_f32(
            &format!("dense_matvec_{bname}"),
            &[
                Arg::F32Mat(ap, bucket.nrows, bucket.ncols),
                Arg::F32(xp),
                Arg::F32(yp),
            ],
        )?;
        Ok(y[..nrows].to_vec())
    }

    /// Default artifact directory (`$DTANS_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var("DTANS_ARTIFACTS")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
    }
}
