//! Mutable registered matrices: an append-only COO delta overlay composed
//! with the immutable encoded base.
//!
//! The dtANS artifact is frozen at encode time — the paper's format has no
//! in-place update story, yet the serving north-star (evolving graphs,
//! periodically retrained weights) needs one. Following SMASH's base+delta
//! design (PAPERS.md), this module keeps the base immutable and absorbs
//! writes into a small sorted side structure:
//!
//! * [`DeltaOverlay`] — a sorted-run COO holding **one entry per mutated
//!   coordinate**. Appending `(r, c, d)` means `A[r,c] += d`; the overlay
//!   stores the *folded effective coefficient* (the coordinate's current
//!   value with every delta added in arrival order), so reads never
//!   re-associate the accumulation and the stored bits are exactly what a
//!   from-scratch sequential application of all deltas would produce.
//! * [`merge`] — materializes the mutated matrix as a fresh CSR: the
//!   coordinate union of base and overlay, overlay entries taking
//!   precedence verbatim. This is the rebuild that compaction re-encodes
//!   ([`crate::store`]); because overlay values are already folded, the
//!   merge moves bits without performing arithmetic — which is what makes
//!   compaction **bit-neutral**: multiplies before and after a compaction,
//!   and appends that land after one, all see identical coefficients.
//! * [`OverlayOperator`] — a [`SpmvOperator`] over `(base CSR, overlay)`
//!   whose per-row kernel walks the same column-ascending union in the
//!   same order, so its results are bit-identical to running the CSR
//!   kernel on the [`merge`]-rebuilt matrix (property-tested across engine
//!   partitions in `rust/tests/delta_overlay.rs`). The engine, router,
//!   solvers and the coalescing SpMM path all work against it unchanged.
//!
//! # Why the base is the CSR original, not the dtANS decoder
//!
//! Bit-identity with a from-scratch rebuild requires interleaving base and
//! overlay terms per row in column order — a coordinate-level walk the
//! entropy-coded operator cannot expose (its decoder reassociates row sums
//! in warp lockstep). A mutated matrix therefore serves CSR-exact
//! arithmetic from its first append onward; the dtANS encoding remains the
//! *persistence* format (versioned artifacts, cold loads, compaction
//! output). `docs/MUTATION.md` documents the trade-off and the
//! version/compaction protocol.

use crate::matrix::csr::Csr;
use crate::spmv::engine::Block;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::{DtansError, Result};
use std::borrow::Cow;
use std::sync::Arc;

/// Append-only delta overlay: a row-major sorted run with at most one
/// entry per coordinate, each holding the coordinate's folded effective
/// coefficient. Immutable once built — [`DeltaOverlay::appended`] returns
/// a new overlay, so in-flight multiplies against the old one are never
/// disturbed (the store swaps overlays under its lock the same way it
/// swaps operators).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeltaOverlay {
    nrows: usize,
    ncols: usize,
    /// Per-row start offsets into `cols`/`vals`, length `nrows + 1`.
    row_ptr: Vec<usize>,
    /// Column per entry, strictly ascending within a row.
    cols: Vec<u32>,
    /// Folded effective coefficient per mutated coordinate.
    vals: Vec<f64>,
}

impl DeltaOverlay {
    /// Empty overlay for a `nrows x ncols` base.
    pub fn empty(nrows: usize, ncols: usize) -> DeltaOverlay {
        DeltaOverlay {
            nrows,
            ncols,
            row_ptr: vec![0; nrows + 1],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Shape `(nrows, ncols)`.
    pub fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    /// Mutated coordinates carried by the overlay.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Is the overlay empty (no mutations at all)?
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Heap bytes of the overlay (the quantity the store's residency
    /// accounting sees; the compaction trigger thresholds on [`Self::nnz`]).
    pub fn size_bytes(&self) -> usize {
        self.row_ptr.len() * 8 + self.cols.len() * 4 + self.vals.len() * 8
    }

    /// Column indices of row `r`'s overlay entries (ascending).
    #[inline]
    pub fn row_cols(&self, r: usize) -> &[u32] {
        &self.cols[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Effective coefficients of row `r`'s overlay entries.
    #[inline]
    pub fn row_vals(&self, r: usize) -> &[f64] {
        &self.vals[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// The overlay's effective coefficient at `(r, c)`, if mutated.
    #[inline]
    pub fn get(&self, r: u32, c: u32) -> Option<f64> {
        let (lo, hi) = (self.row_ptr[r as usize], self.row_ptr[r as usize + 1]);
        self.cols[lo..hi].binary_search(&c).ok().map(|i| self.vals[lo + i])
    }

    /// A new overlay with `updates` accumulated on top of this one over
    /// `base` (the immutable CSR the overlay composes with — needed
    /// because a coordinate entering the overlay starts folding from its
    /// base value).
    ///
    /// Accumulation is order-deterministic: each update means
    /// `A[r,c] += d`, and a coordinate's updates (within this batch and
    /// across batches) fold into the stored effective value **in arrival
    /// order**. Replaying the same batches in the same order therefore
    /// reproduces every stored bit — the property the stress driver's
    /// serial-replay oracle leans on — and the fold is exactly what a
    /// from-scratch sequential application of the deltas to `base` yields.
    ///
    /// Fails on a base shape mismatch, out-of-bounds coordinates, or
    /// non-finite deltas (a NaN would silently poison every future
    /// multiply of that row).
    pub fn appended(&self, base: &Csr, updates: &[(u32, u32, f64)]) -> Result<DeltaOverlay> {
        if (base.nrows, base.ncols) != (self.nrows, self.ncols) {
            return Err(DtansError::Dimension(format!(
                "overlay {:?} vs base {}x{}",
                self.dims(),
                base.nrows,
                base.ncols
            )));
        }
        for &(r, c, v) in updates {
            if r as usize >= self.nrows || c as usize >= self.ncols {
                return Err(DtansError::InvalidMatrix(format!(
                    "delta ({r},{c}) out of bounds for {}x{}",
                    self.nrows, self.ncols
                )));
            }
            if !v.is_finite() {
                return Err(DtansError::InvalidMatrix(format!(
                    "non-finite delta {v} at ({r},{c})"
                )));
            }
        }
        // Stable sort keeps one coordinate's updates contiguous *in
        // arrival order*, so the fold below is order-deterministic.
        let mut idx: Vec<usize> = (0..updates.len()).collect();
        idx.sort_by_key(|&i| ((updates[i].0 as u64) << 32) | updates[i].1 as u64);
        let mut batch: Vec<(u32, u32, f64)> = Vec::new();
        let mut k = 0;
        while k < idx.len() {
            let (r, c, _) = updates[idx[k]];
            // Fold from the coordinate's current effective value: a prior
            // overlay entry, else the base entry, else structural zero.
            let mut eff = self
                .get(r, c)
                .or_else(|| {
                    base.row_cols(r as usize)
                        .binary_search(&c)
                        .ok()
                        .map(|i| base.row_vals(r as usize)[i])
                })
                .unwrap_or(0.0);
            while k < idx.len() && (updates[idx[k]].0, updates[idx[k]].1) == (r, c) {
                eff += updates[idx[k]].2;
                k += 1;
            }
            batch.push((r, c, eff));
        }
        // Union-merge the batch into the sorted run; batch entries replace
        // existing overlay entries (the fold above already started from
        // them).
        let mut out = DeltaOverlay::empty(self.nrows, self.ncols);
        out.cols.reserve(self.nnz() + batch.len());
        out.vals.reserve(self.nnz() + batch.len());
        let mut j = 0;
        for r in 0..self.nrows {
            let (lo, hi) = (self.row_ptr[r], self.row_ptr[r + 1]);
            let mut i = lo;
            while i < hi || (j < batch.len() && batch[j].0 as usize == r) {
                let from_batch = j < batch.len() && batch[j].0 as usize == r;
                if i < hi && (!from_batch || self.cols[i] < batch[j].1) {
                    out.cols.push(self.cols[i]);
                    out.vals.push(self.vals[i]);
                    i += 1;
                } else {
                    if i < hi && self.cols[i] == batch[j].1 {
                        i += 1; // replaced by the batch's fold
                    }
                    out.cols.push(batch[j].1);
                    out.vals.push(batch[j].2);
                    j += 1;
                }
            }
            out.row_ptr[r + 1] = out.cols.len();
        }
        debug_assert_eq!(j, batch.len());
        Ok(out)
    }
}

/// Materialize `base + overlay` as a fresh CSR: the column-ascending
/// coordinate union per row, overlay entries taking precedence verbatim
/// (their values are already folded, so the merge performs no float
/// arithmetic at all). A mutation that lands exactly on `0.0` stays an
/// explicit entry, so the rebuilt row structure — and therefore the CSR
/// kernel's term order — matches [`OverlayOperator`]'s walk exactly.
pub fn merge(base: &Csr, overlay: &DeltaOverlay) -> Result<Csr> {
    if overlay.dims() != (base.nrows, base.ncols) {
        return Err(DtansError::Dimension(format!(
            "overlay {:?} vs base {}x{}",
            overlay.dims(),
            base.nrows,
            base.ncols
        )));
    }
    let mut out = Csr::new(base.nrows, base.ncols);
    out.cols.reserve(base.nnz() + overlay.nnz());
    out.vals.reserve(base.nnz() + overlay.nnz());
    for r in 0..base.nrows {
        let (bc, bv) = (base.row_cols(r), base.row_vals(r));
        let (dc, dv) = (overlay.row_cols(r), overlay.row_vals(r));
        let (mut i, mut j) = (0, 0);
        while i < bc.len() && j < dc.len() {
            if bc[i] < dc[j] {
                out.cols.push(bc[i]);
                out.vals.push(bv[i]);
                i += 1;
            } else {
                if bc[i] == dc[j] {
                    i += 1; // overridden
                }
                out.cols.push(dc[j]);
                out.vals.push(dv[j]);
                j += 1;
            }
        }
        out.cols.extend_from_slice(&bc[i..]);
        out.vals.extend_from_slice(&bv[i..]);
        out.cols.extend_from_slice(&dc[j..]);
        out.vals.extend_from_slice(&dv[j..]);
        out.row_ptr[r + 1] = out.cols.len();
    }
    Ok(out)
}

/// [`SpmvOperator`] over an immutable CSR base plus a [`DeltaOverlay`]:
/// the kernel surface a mutated matrix serves through between appends and
/// compactions. Work units are rows (like CSR); the per-row kernel is the
/// scalar CSR dot product over the coordinate *union* (overlay values
/// taking precedence), so every result is bit-identical to
/// [`crate::spmv::spmv_csr`] on the [`merge`]-rebuilt matrix.
pub struct OverlayOperator {
    base: Arc<Csr>,
    delta: Arc<DeltaOverlay>,
    /// Union per-row entry counts as a monotone prefix (length
    /// `nrows + 1`) — the engine's partitioning cost, same units as CSR's
    /// `row_ptr`.
    prefix: Vec<usize>,
}

impl OverlayOperator {
    /// Compose `base` with `delta` (shapes must agree).
    pub fn new(base: Arc<Csr>, delta: Arc<DeltaOverlay>) -> Result<OverlayOperator> {
        if delta.dims() != (base.nrows, base.ncols) {
            return Err(DtansError::Dimension(format!(
                "overlay {:?} vs base {}x{}",
                delta.dims(),
                base.nrows,
                base.ncols
            )));
        }
        let mut prefix = Vec::with_capacity(base.nrows + 1);
        prefix.push(0);
        let mut total = 0usize;
        for r in 0..base.nrows {
            let (bc, dc) = (base.row_cols(r), delta.row_cols(r));
            let (mut i, mut j, mut n) = (0, 0, 0usize);
            while i < bc.len() && j < dc.len() {
                if bc[i] < dc[j] {
                    i += 1;
                } else if bc[i] > dc[j] {
                    j += 1;
                } else {
                    i += 1;
                    j += 1;
                }
                n += 1;
            }
            n += bc.len() - i + dc.len() - j;
            total += n;
            prefix.push(total);
        }
        Ok(OverlayOperator { base, delta, prefix })
    }

    /// The immutable base CSR.
    pub fn base(&self) -> &Arc<Csr> {
        &self.base
    }

    /// The composed overlay.
    pub fn delta(&self) -> &Arc<DeltaOverlay> {
        &self.delta
    }

    /// One row's dot product over the column-ascending union walk — the
    /// same terms in the same order as [`crate::spmv::spmv_csr`] on the
    /// merged CSR, overlay coefficients used verbatim where present.
    #[inline]
    fn row_dot(&self, r: usize, x: &[f64]) -> f64 {
        let (bc, bv) = (self.base.row_cols(r), self.base.row_vals(r));
        let (dc, dv) = (self.delta.row_cols(r), self.delta.row_vals(r));
        let (mut i, mut j) = (0, 0);
        let mut acc = 0.0;
        while i < bc.len() && j < dc.len() {
            if bc[i] < dc[j] {
                acc += bv[i] * x[bc[i] as usize];
                i += 1;
            } else {
                if bc[i] == dc[j] {
                    i += 1; // overridden
                }
                acc += dv[j] * x[dc[j] as usize];
                j += 1;
            }
        }
        while i < bc.len() {
            acc += bv[i] * x[bc[i] as usize];
            i += 1;
        }
        while j < dc.len() {
            acc += dv[j] * x[dc[j] as usize];
            j += 1;
        }
        acc
    }
}

impl SpmvOperator for OverlayOperator {
    fn dims(&self) -> (usize, usize) {
        (self.base.nrows, self.base.ncols)
    }

    /// Stored entries of the composition — what the merged CSR would
    /// report (base and overlay coordinates union'd, shared ones counted
    /// once).
    fn nnz(&self) -> usize {
        *self.prefix.last().unwrap_or(&0)
    }

    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.prefix)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        debug_assert_eq!(y_seg.len(), block.end - block.start);
        for (i, r) in (block.start..block.end).enumerate() {
            let acc = self.row_dot(r, x);
            y_seg[i] += acc;
        }
        Ok(())
    }

    /// Fused path mirroring the CSR kernel's: same per-row accumulator,
    /// `alpha·acc + beta·y` in place of the accumulate — bit-identical to
    /// the unfused compose, and to the merged CSR's own fused path.
    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        debug_assert_eq!(y_seg.len(), block.end - block.start);
        for (i, r) in (block.start..block.end).enumerate() {
            let acc = self.row_dot(r, x);
            y_seg[i] = alpha * acc + beta * y_seg[i];
        }
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        SpmvOperator::resident_bytes(self.base.as_ref())
            + self.delta.size_bytes()
            + self.prefix.len() * 8
    }

    fn format_tag(&self) -> &'static str {
        "overlay"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample(n: usize, seed: u64) -> Csr {
        let mut m = banded(n, 3);
        assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(seed));
        m
    }

    fn tiny_base() -> Csr {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(1, 1, 3.0);
        Csr::from_coo(&coo)
    }

    #[test]
    fn appended_folds_from_current_effective_value_in_arrival_order() {
        let base = tiny_base();
        let d0 = DeltaOverlay::empty(3, 3);
        assert!(d0.is_empty());
        // (1,1) exists in the base (3.0) and gets two in-batch deltas:
        // fold is (3.0 + 2.0) + 3.0. (2,0) is structurally zero: 0.0 + 4.0.
        let d1 = d0.appended(&base, &[(1, 1, 2.0), (2, 0, 4.0), (1, 1, 3.0)]).unwrap();
        assert_eq!(d1.nnz(), 2);
        assert_eq!(d1.get(1, 1), Some((3.0 + 2.0) + 3.0));
        assert_eq!(d1.get(2, 0), Some(4.0));
        // A second batch folds from the stored effective value.
        let d2 = d1.appended(&base, &[(1, 1, 0.5)]).unwrap();
        assert_eq!(d2.get(1, 1), Some(((3.0 + 2.0) + 3.0) + 0.5));
        // The original overlays are untouched (functional update).
        assert_eq!(d1.get(1, 1), Some((3.0 + 2.0) + 3.0));
        assert!(d0.is_empty());
    }

    #[test]
    fn appended_rejects_bad_input() {
        let base = Csr::new(2, 2);
        let d = DeltaOverlay::empty(2, 2);
        assert!(d.appended(&base, &[(2, 0, 1.0)]).is_err());
        assert!(d.appended(&base, &[(0, 2, 1.0)]).is_err());
        assert!(d.appended(&base, &[(0, 0, f64::NAN)]).is_err());
        assert!(d.appended(&base, &[(0, 0, f64::INFINITY)]).is_err());
        assert!(d.appended(&Csr::new(3, 2), &[]).is_err(), "shape mismatch");
        assert!(d.appended(&base, &[]).unwrap().is_empty());
    }

    #[test]
    fn merge_takes_overlay_values_verbatim() {
        let base = tiny_base();
        let d = DeltaOverlay::empty(3, 3)
            .appended(&base, &[(0, 1, 10.0), (0, 2, -2.0), (1, 1, 0.25)])
            .unwrap();
        let m = merge(&base, &d).unwrap();
        m.validate().unwrap();
        assert_eq!(m.row_cols(0), &[0, 1, 2]);
        assert_eq!(m.row_vals(0), &[1.0, 10.0, 0.0]); // 2.0 - 2.0 stays explicit
        assert_eq!(m.row_vals(1), &[3.0 + 0.25]);
        assert_eq!(m.row_len(2), 0);
        // Shape mismatch is refused.
        assert!(merge(&base, &DeltaOverlay::empty(4, 3)).is_err());
    }

    #[test]
    fn compaction_is_bit_neutral_for_later_appends() {
        // Appending after a merge (compaction) must fold from the same
        // bits as appending onto the live overlay.
        let base = sample(120, 3);
        let d1 = DeltaOverlay::empty(120, 120)
            .appended(&base, &[(5, 5, 0.1), (5, 5, 0.7), (40, 2, -1.5)])
            .unwrap();
        // Path A: keep appending on the overlay.
        let a = d1.appended(&base, &[(5, 5, 0.3), (7, 7, 2.0)]).unwrap();
        let ma = merge(&base, &a).unwrap();
        // Path B: compact (merge) first, then append to the new base.
        let compacted = merge(&base, &d1).unwrap();
        let b = DeltaOverlay::empty(120, 120)
            .appended(&compacted, &[(5, 5, 0.3), (7, 7, 2.0)])
            .unwrap();
        let mb = merge(&compacted, &b).unwrap();
        assert_eq!(ma, mb, "merge-then-append must equal append-then-merge bitwise");
    }

    #[test]
    fn operator_is_bitwise_equal_to_merged_csr_kernel() {
        let base = Arc::new(sample(300, 11));
        let mut delta = DeltaOverlay::empty(300, 300);
        let mut rng = Xoshiro256::seeded(12);
        for _ in 0..5 {
            let batch: Vec<(u32, u32, f64)> = (0..40)
                .map(|_| {
                    (
                        rng.below(300) as u32,
                        rng.below(300) as u32,
                        rng.next_f64() - 0.5,
                    )
                })
                .collect();
            delta = delta.appended(&base, &batch).unwrap();
        }
        let delta = Arc::new(delta);
        let op = OverlayOperator::new(Arc::clone(&base), Arc::clone(&delta)).unwrap();
        let rebuilt = merge(&base, &delta).unwrap();
        assert_eq!(SpmvOperator::nnz(&op), rebuilt.nnz());
        assert_eq!(op.cost_prefix().as_ref(), &rebuilt.row_ptr[..]);
        let x = crate::testkit::seeded_vector(300, 13);
        let mut want = vec![0.0; 300];
        crate::spmv::spmv_csr(&rebuilt, &x, &mut want).unwrap();
        let mut got = vec![0.0; 300];
        let full = Block { start: 0, end: 300, cost: rebuilt.nnz() };
        op.run_range(full, &x, &mut got).unwrap();
        assert_eq!(got, want, "run_range must match the merged CSR bitwise");
        // Fused path vs the merged CSR's fused path, also bitwise.
        let y0: Vec<f64> = (0..300).map(|i| (i as f64) * 0.125 - 3.0).collect();
        let mut a = y0.clone();
        op.run_range_axpby(full, &x, -0.5, 1.25, &mut a).unwrap();
        let mut b = y0.clone();
        crate::spmv::engine::SpmvEngine::serial()
            .run_axpby(&rebuilt, &x, -0.5, 1.25, &mut b)
            .unwrap();
        assert_eq!(a, b, "fused path must match the merged CSR bitwise");
    }

    #[test]
    fn operator_refuses_shape_mismatch() {
        let base = Arc::new(sample(50, 1));
        let delta = Arc::new(DeltaOverlay::empty(51, 50));
        assert!(OverlayOperator::new(base, delta).is_err());
    }
}
