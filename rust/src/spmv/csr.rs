//! CSR SpMVM kernels: the scalar (one row per thread) and vector (one warp
//! per row) variants of cuSPARSE/Bell-Garland [34]. On the CPU both reduce
//! to the same arithmetic; they differ in the *memory schedule* the GPU
//! simulator charges, so both exist as named kernels.

use crate::matrix::csr::Csr;
use crate::util::error::Result;

/// Scalar CSR kernel: each row's dot product in sequence.
pub fn spmv_csr(m: &Csr, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    for r in 0..m.nrows {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        let mut acc = 0.0;
        for i in lo..hi {
            acc += m.vals[i] * x[m.cols[i] as usize];
        }
        y[r] += acc;
    }
    Ok(())
}

/// Vector CSR kernel: rows processed in warp-sized gangs with a lane-strided
/// inner loop (the GPU schedule; numerically reassociated, which matters
/// only at the f64 ulp level).
pub fn spmv_csr_vector(m: &Csr, x: &[f64], y: &mut [f64], warp: usize) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    let warp = warp.max(1);
    for r in 0..m.nrows {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        // Lane-strided partial sums, then a tree-style reduction.
        let nlanes = warp.min(hi - lo).max(1);
        let mut partial = vec![0.0f64; nlanes];
        for (k, i) in (lo..hi).enumerate() {
            partial[k % nlanes] += m.vals[i] * x[m.cols[i] as usize];
        }
        y[r] += partial.iter().sum::<f64>();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;
    use crate::spmv::dense::spmv_dense;
    use crate::util::propcheck::assert_close;

    fn example() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[(0, 1, 7.0), (0, 3, 5.0), (1, 0, 3.0), (1, 2, 2.0), (2, 1, 4.0), (3, 3, 1.0)] {
            coo.push(r, c, v);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn matches_dense() {
        let m = example();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.5; 4];
        let mut yd = vec![0.5; 4];
        spmv_csr(&m, &x, &mut y).unwrap();
        spmv_dense(&m.to_dense(), 4, 4, &x, &mut yd).unwrap();
        assert_close(&y, &yd, 1e-12, 0.0).unwrap();
    }

    #[test]
    fn vector_variant_matches() {
        let m = example();
        let x = vec![1.0, -2.0, 0.25, 4.0];
        let mut y1 = vec![0.0; 4];
        let mut y2 = vec![0.0; 4];
        spmv_csr(&m, &x, &mut y1).unwrap();
        spmv_csr_vector(&m, &x, &mut y2, 32).unwrap();
        assert_close(&y1, &y2, 1e-12, 1e-15).unwrap();
    }

    #[test]
    fn accumulates_into_y() {
        let m = example();
        let x = vec![1.0; 4];
        let mut y = vec![100.0; 4];
        spmv_csr(&m, &x, &mut y).unwrap();
        assert_eq!(y[3], 101.0);
    }
}
