//! Integration: CSR-dtANS encode → serialize → load → decode roundtrips
//! across the corpus, both parameter presets and precisions.

use dtans::ans::AnsParams;
use dtans::eval::{build_corpus, CorpusScale};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::format::serialize;
use dtans::matrix::Precision;

fn opts_matrix() -> Vec<EncodeOptions> {
    vec![
        EncodeOptions::default(),
        EncodeOptions {
            params: AnsParams::KERNEL,
            ..Default::default()
        },
        EncodeOptions {
            precision: Precision::F32,
            ..Default::default()
        },
        EncodeOptions {
            delta_encode: false,
            ..Default::default()
        },
    ]
}

#[test]
fn corpus_roundtrips_all_option_combinations() {
    let corpus = build_corpus(&CorpusScale { max_nnz: 6000, steps: 3 }, 99);
    assert!(corpus.len() >= 15);
    for (i, e) in corpus.iter().enumerate() {
        // Rotate option combos across corpus entries (full cross product
        // would be slow; every combo still sees many matrices).
        let opts = &opts_matrix()[i % 4];
        let enc = CsrDtans::encode(&e.csr, opts)
            .unwrap_or_else(|err| panic!("{}: encode failed: {err}", e.name));
        let back = enc
            .decode_to_csr()
            .unwrap_or_else(|err| panic!("{}: decode failed: {err}", e.name));
        let want = match opts.precision {
            Precision::F64 => e.csr.clone(),
            Precision::F32 => e.csr.round_to_f32(),
        };
        assert_eq!(back, want, "{} with {opts:?}", e.name);
    }
}

#[test]
fn corpus_serialization_roundtrips() {
    let corpus = build_corpus(&CorpusScale { max_nnz: 3000, steps: 2 }, 7);
    for e in corpus.iter().take(10) {
        let enc = CsrDtans::encode(&e.csr, &EncodeOptions::default()).unwrap();
        let mut buf = Vec::new();
        serialize::write_to(&enc, &mut buf).unwrap();
        let back = serialize::read_from(std::io::Cursor::new(&buf)).unwrap();
        assert_eq!(back.decode_to_csr().unwrap(), enc.decode_to_csr().unwrap(), "{}", e.name);
        // Serialized size tracks the size report's stream component.
        assert!(buf.len() >= enc.size_report().stream);
    }
}

#[test]
fn corrupted_corpus_containers_always_fail_to_load() {
    // The testkit corruption engine over real corpus encodes: every fault
    // mode must surface as a load error (typed `DtansError`), never a
    // panic and never a silently different decode — lossless means the
    // container either roundtrips exactly or refuses.
    use dtans::testkit::faults::{corrupt, ALL_FAULT_MODES};
    let corpus = build_corpus(&CorpusScale { max_nnz: 2000, steps: 2 }, 11);
    for (i, e) in corpus.iter().step_by(4).take(5).enumerate() {
        let enc = CsrDtans::encode(&e.csr, &EncodeOptions::default()).unwrap();
        let mut buf = Vec::new();
        serialize::write_to(&enc, &mut buf).unwrap();
        for mode in ALL_FAULT_MODES {
            for seed in 0..6u64 {
                let bad = corrupt(&buf, mode, seed.wrapping_add(i as u64) << 3);
                assert!(
                    serialize::read_from(std::io::Cursor::new(&bad)).is_err(),
                    "{}: {mode:?} seed {seed} loaded successfully",
                    e.name
                );
            }
        }
    }
}

#[test]
fn size_report_components_are_consistent() {
    let corpus = build_corpus(&CorpusScale { max_nnz: 20_000, steps: 3 }, 3);
    for e in &corpus {
        let enc = CsrDtans::encode(&e.csr, &EncodeOptions::default()).unwrap();
        let r = enc.size_report();
        assert_eq!(
            r.total,
            r.header + r.tables + r.dicts + r.stream + r.row_lens + r.slice_offsets
                + r.escapes + r.escape_offsets,
            "{}",
            e.name
        );
        assert_eq!(r.stream, enc.stream.len() * 4);
        assert_eq!(r.row_lens, enc.nrows * 4);
        // Tables are the paper's constant: 2 domains x K slots x 4 B.
        assert_eq!(r.tables, 2 * 4096 * 4);
    }
}

#[test]
fn mtx_to_dtans_file_pipeline() {
    // The CLI path: mtx -> encode -> save -> load -> decode -> mtx.
    let dir = std::env::temp_dir().join("dtans_it_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let m = dtans::matrix::gen::structured::stencil2d5(20, 20);
    let mtx_path = dir.join("a.mtx");
    dtans::matrix::mtx::save_mtx(&m, &mtx_path).unwrap();
    let loaded = dtans::matrix::mtx::load_mtx_csr(&mtx_path).unwrap();
    assert_eq!(loaded, m);
    let enc = CsrDtans::encode(&loaded, &EncodeOptions::default()).unwrap();
    let bin = dir.join("a.dtans");
    serialize::save(&enc, &bin).unwrap();
    let enc2 = serialize::load(&bin).unwrap();
    assert_eq!(enc2.decode_to_csr().unwrap(), m);
    let _ = std::fs::remove_dir_all(&dir);
}
