//! Warp interleaving of per-row dtANS word streams (§II-A "Interleaving
//! for warps", §IV-B "Lack of efficient SIMT parallelism").
//!
//! All 32 threads of a warp share one word stream. At every *load event*
//! the active lanes read consecutive words (one coalesced transaction); a
//! lane's offset within the event is its rank among the active lanes — on
//! the GPU a `__ballot_sync` + two `popc`s, here an explicit scan.
//!
//! The event schedule per slice is fully determined by the rows' segment
//! counts and branch patterns (which the encoder's base pass recorded):
//!
//! 1. initial words `k = 0..o` for every non-empty row (o events);
//! 2. per segment `t` of any producing row, in order:
//!    check `g = 0..f` (lanes whose branch says *load*), then the
//!    unconditional words `k = f..o` (all producing lanes).
//!
//! The decoder replays the same schedule with a single stream cursor.

use crate::ans::dtans::RowEncoding;
use crate::ans::params::AnsParams;

/// Interleave the per-row encodings of one slice into a shared stream.
/// `rows.len()` is at most the warp width (32) but any lane count works;
/// missing rows at the slice tail are simply absent.
pub fn interleave_slice(p: &AnsParams, rows: &[RowEncoding]) -> Vec<u32> {
    let (o, f) = (p.o as usize, p.f as usize);
    let mut cursors = vec![0usize; rows.len()];
    let total: usize = rows.iter().map(|r| r.words.len()).sum();
    let mut out = Vec::with_capacity(total);
    let take = |lane: usize, cursors: &mut [usize], out: &mut Vec<u32>| {
        out.push(rows[lane].words[cursors[lane]]);
        cursors[lane] += 1;
    };

    // Initial o words.
    for _k in 0..o {
        for lane in 0..rows.len() {
            if rows[lane].nseg > 0 {
                take(lane, &mut cursors, &mut out);
            }
        }
    }
    let max_seg = rows.iter().map(|r| r.nseg).max().unwrap_or(0);
    for t in 0..max_seg.saturating_sub(1) {
        // A lane produces next-segment words while t < nseg - 1.
        for g in 0..f {
            for lane in 0..rows.len() {
                if t + 1 < rows[lane].nseg && !rows[lane].branches[t * f + g] {
                    take(lane, &mut cursors, &mut out);
                }
            }
        }
        for _k in f..o {
            for lane in 0..rows.len() {
                if t + 1 < rows[lane].nseg {
                    take(lane, &mut cursors, &mut out);
                }
            }
        }
    }
    debug_assert_eq!(out.len(), total, "all row words must be consumed");
    debug_assert!(cursors.iter().zip(rows).all(|(&c, r)| c == r.words.len()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::dtans::{decode_row, encode_row};
    use crate::ans::histogram::normalize_counts;
    use crate::ans::tables::CodingTables;
    use crate::ans::AnsParams;
    use crate::util::rng::Xoshiro256;

    fn tables(p: &AnsParams, rng: &mut Xoshiro256) -> CodingTables {
        let counts: Vec<u64> = (0..200).map(|_| 1 + rng.below(500)).collect();
        CodingTables::build(p, &normalize_counts(&counts, p.k(), p.m()).unwrap()).unwrap()
    }

    /// Scalar replay of the interleaved schedule to recover per-row words.
    fn deinterleave(p: &AnsParams, rows: &[RowEncoding], stream: &[u32]) -> Vec<Vec<u32>> {
        let (o, f) = (p.o as usize, p.f as usize);
        let mut pos = 0;
        let mut out: Vec<Vec<u32>> = rows.iter().map(|_| Vec::new()).collect();
        for _k in 0..o {
            for (lane, r) in rows.iter().enumerate() {
                if r.nseg > 0 {
                    out[lane].push(stream[pos]);
                    pos += 1;
                }
            }
        }
        let max_seg = rows.iter().map(|r| r.nseg).max().unwrap_or(0);
        for t in 0..max_seg.saturating_sub(1) {
            for g in 0..f {
                for (lane, r) in rows.iter().enumerate() {
                    if t + 1 < r.nseg && !r.branches[t * f + g] {
                        out[lane].push(stream[pos]);
                        pos += 1;
                    }
                }
            }
            for _k in f..o {
                for (lane, r) in rows.iter().enumerate() {
                    if t + 1 < r.nseg {
                        out[lane].push(stream[pos]);
                        pos += 1;
                    }
                }
            }
        }
        assert_eq!(pos, stream.len());
        out
    }

    #[test]
    fn interleave_roundtrips_through_schedule() {
        let p = AnsParams::KERNEL;
        let mut rng = Xoshiro256::seeded(42);
        let t = tables(&p, &mut rng);
        let tabs = [&t];
        // 32 rows of varying lengths, including empty ones.
        let mut rows = Vec::new();
        let mut all_syms = Vec::new();
        for lane in 0..32usize {
            let nseg = if lane % 7 == 0 { 0 } else { rng.below_usize(9) };
            let syms: Vec<u16> = (0..nseg * p.l as usize)
                .map(|_| rng.below(t.num_symbols() as u64) as u16)
                .collect();
            rows.push(encode_row(&p, &tabs, &syms).unwrap());
            all_syms.push(syms);
        }
        let stream = interleave_slice(&p, &rows);
        let per_row = deinterleave(&p, &rows, &stream);
        for lane in 0..32 {
            assert_eq!(per_row[lane], rows[lane].words, "lane {lane}");
            let dec = decode_row(&p, &tabs, &per_row[lane], all_syms[lane].len()).unwrap();
            assert_eq!(dec, all_syms[lane], "lane {lane}");
        }
    }

    #[test]
    fn empty_slice() {
        let p = AnsParams::KERNEL;
        let rows: Vec<RowEncoding> = Vec::new();
        assert!(interleave_slice(&p, &rows).is_empty());
    }

    #[test]
    fn single_row_slice_is_identity() {
        let p = AnsParams::KERNEL;
        let mut rng = Xoshiro256::seeded(5);
        let t = tables(&p, &mut rng);
        let syms: Vec<u16> = (0..6 * p.l as usize)
            .map(|_| rng.below(t.num_symbols() as u64) as u16)
            .collect();
        let enc = encode_row(&p, &[&t], &syms).unwrap();
        let stream = interleave_slice(&p, std::slice::from_ref(&enc));
        assert_eq!(stream, enc.words);
    }
}
