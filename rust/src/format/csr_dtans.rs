//! The CSR-dtANS container (§IV): a CSR matrix whose delta-encoded column
//! indices and values are entropy-coded with dtANS, stored as
//! warp-interleaved word streams plus shared coding tables.

use super::interleave::interleave_slice;
use super::symbolize::{Domain, SymbolPicker};
use crate::ans::dtans::{encode_row, RowDecoder, RowEncoding};
use crate::ans::params::AnsParams;
use crate::ans::tables::CodingTables;
use crate::matrix::csr::Csr;
use crate::matrix::Precision;
use crate::util::error::{DtansError, Result};
use std::collections::HashMap;

/// Warp width: rows per slice, lanes per decode group.
pub const WARP: usize = 32;

/// Encoding options.
#[derive(Debug, Clone, Copy)]
pub struct EncodeOptions {
    /// Codec parameters (PAPER by default).
    pub params: AnsParams,
    /// Value precision (affects symbolization and size accounting).
    pub precision: Precision,
    /// Delta-encode column indices before entropy coding (§IV-A). Disabled
    /// only by the ablation benchmarks.
    pub delta_encode: bool,
}

impl Default for EncodeOptions {
    fn default() -> Self {
        EncodeOptions {
            params: AnsParams::PAPER,
            precision: Precision::F64,
            delta_encode: true,
        }
    }
}

/// Byte-size breakdown of a CSR-dtANS matrix (the paper's Fig. 6 size
/// accounting: constant table cost + stream + per-row n + escapes).
#[derive(Debug, Clone, Copy, Default)]
pub struct SizeReport {
    /// Fixed header.
    pub header: usize,
    /// Both K-slot tables (4 B packed entry per slot).
    pub tables: usize,
    /// Dictionary payload arrays.
    pub dicts: usize,
    /// Interleaved word streams.
    pub stream: usize,
    /// Per-row nonzero counts (the paper's 4-byte `n` per row).
    pub row_lens: usize,
    /// Per-slice stream offsets.
    pub slice_offsets: usize,
    /// Escaped raw payloads (side streams).
    pub escapes: usize,
    /// Per-row escape offsets (present only when escapes exist).
    pub escape_offsets: usize,
    /// Sum of all components.
    pub total: usize,
}

/// A CSR matrix compressed with dtANS.
#[derive(Debug, Clone)]
pub struct CsrDtans {
    /// Codec parameters.
    pub params: AnsParams,
    /// Value precision.
    pub precision: Precision,
    /// Whether column indices were delta-encoded.
    pub delta_encode: bool,
    /// Logical shape.
    pub nrows: usize,
    /// Columns.
    pub ncols: usize,
    /// Nonzeros.
    pub nnz: usize,
    /// Delta-domain dictionary/escape/multiplicity info.
    pub delta_domain: Domain,
    /// Value-domain dictionary/escape/multiplicity info.
    pub value_domain: Domain,
    /// Delta coding tables (K slots).
    pub delta_tables: CodingTables,
    /// Value coding tables (K slots).
    pub value_tables: CodingTables,
    /// Per-row nonzero count.
    pub row_nnz: Vec<u32>,
    /// Word offset of each slice's interleaved stream (len = nslices + 1).
    pub slice_offsets: Vec<u32>,
    /// All slices' interleaved words.
    pub stream: Vec<u32>,
    /// Escaped delta payloads, row-major.
    pub delta_escapes: Vec<u32>,
    /// Escaped value payloads (bit patterns), row-major.
    pub value_escapes: Vec<u64>,
    /// Per-row start into `delta_escapes` (len = nrows + 1).
    pub delta_esc_offsets: Vec<u32>,
    /// Per-row start into `value_escapes` (len = nrows + 1).
    pub value_esc_offsets: Vec<u32>,
}

#[inline]
fn value_payload(v: f64, prec: Precision) -> u64 {
    match prec {
        Precision::F64 => v.to_bits(),
        Precision::F32 => (v as f32).to_bits() as u64,
    }
}

#[inline]
fn value_from_payload(p: u64, prec: Precision) -> f64 {
    match prec {
        Precision::F64 => f64::from_bits(p),
        Precision::F32 => f32::from_bits(p as u32) as f64,
    }
}

impl CsrDtans {
    /// Nonzeros per segment (`l / 2`: one delta + one value symbol each).
    #[inline]
    pub fn nnz_per_segment(&self) -> usize {
        self.params.l as usize / 2
    }

    /// Number of row slices.
    pub fn nslices(&self) -> usize {
        self.nrows.div_ceil(WARP)
    }

    /// Segments of row `r`.
    #[inline]
    pub fn row_segments(&self, r: usize) -> usize {
        (self.row_nnz[r] as usize).div_ceil(self.nnz_per_segment())
    }

    /// Encode with default options at the given precision.
    pub fn encode_f64(csr: &Csr, opts: &EncodeOptions) -> Result<CsrDtans> {
        Self::encode(csr, opts)
    }

    /// Encode a CSR matrix into CSR-dtANS.
    pub fn encode(csr: &Csr, opts: &EncodeOptions) -> Result<CsrDtans> {
        opts.params.validate()?;
        let p = opts.params;
        if p.l % 2 != 0 {
            return Err(DtansError::InvalidParams(
                "l must be even (delta+value per nonzero)".into(),
            ));
        }
        let prec = opts.precision;
        let nps = p.l as usize / 2; // nonzeros per segment

        // ---- Pass 1: histograms over delta and value payloads. ----
        let mut dcounts: HashMap<u64, u64> = HashMap::new();
        let mut vcounts: HashMap<u64, u64> = HashMap::new();
        let mut deltas: Vec<u32> = Vec::with_capacity(csr.nnz());
        for r in 0..csr.nrows {
            let cols = csr.row_cols(r);
            let mut prev = 0u32;
            for (i, &c) in cols.iter().enumerate() {
                let d = if i == 0 || !opts.delta_encode { c } else { c - prev };
                deltas.push(d);
                *dcounts.entry(d as u64).or_insert(0) += 1;
                prev = c;
            }
            for &v in csr.row_vals(r) {
                *vcounts.entry(value_payload(v, prec)).or_insert(0) += 1;
            }
        }

        let value_bits = 8 * prec.value_bytes() as u32;
        let delta_domain = Domain::build(&dcounts, &p, 32)?;
        let value_domain = Domain::build(&vcounts, &p, value_bits)?;
        let delta_tables = CodingTables::build(&p, &delta_domain.mult)?;
        let value_tables = CodingTables::build(&p, &value_domain.mult)?;
        let tabs = [&delta_tables, &value_tables];

        // ---- Pass 2: symbolize and encode each row. ----
        let mut picker_d = SymbolPicker::default();
        let mut picker_v = SymbolPicker::default();
        let mut row_encs: Vec<RowEncoding> = Vec::with_capacity(csr.nrows);
        let mut delta_escapes = Vec::new();
        let mut value_escapes = Vec::new();
        let mut delta_esc_offsets = Vec::with_capacity(csr.nrows + 1);
        let mut value_esc_offsets = Vec::with_capacity(csr.nrows + 1);
        delta_esc_offsets.push(0u32);
        value_esc_offsets.push(0u32);
        let mut syms: Vec<u16> = Vec::new();
        let mut nz_cursor = 0usize;
        for r in 0..csr.nrows {
            let nnz_r = csr.row_len(r);
            let nseg = nnz_r.div_ceil(nps);
            syms.clear();
            for i in 0..nseg * nps {
                if i < nnz_r {
                    let d = deltas[nz_cursor + i] as u64;
                    let (ds, desc) = delta_domain.sym_for(d, &mut picker_d);
                    if desc {
                        delta_escapes.push(d as u32);
                    }
                    syms.push(ds);
                    let vp = value_payload(csr.row_vals(r)[i], prec);
                    let (vs, vesc) = value_domain.sym_for(vp, &mut picker_v);
                    if vesc {
                        value_escapes.push(vp);
                    }
                    syms.push(vs);
                } else {
                    // Padding (§IV-F): any symbol; the decoder knows n and
                    // ignores it. Pads are never escape symbols.
                    syms.push(delta_domain.pad_sym);
                    syms.push(value_domain.pad_sym);
                }
            }
            nz_cursor += nnz_r;
            row_encs.push(encode_row(&p, &tabs, &syms)?);
            delta_esc_offsets.push(delta_escapes.len() as u32);
            value_esc_offsets.push(value_escapes.len() as u32);
        }

        // ---- Pass 3: warp-interleave slices. ----
        let nslices = csr.nrows.div_ceil(WARP);
        let mut stream = Vec::new();
        let mut slice_offsets = Vec::with_capacity(nslices + 1);
        slice_offsets.push(0u32);
        for s in 0..nslices {
            let r0 = s * WARP;
            let r1 = (r0 + WARP).min(csr.nrows);
            let words = interleave_slice(&p, &row_encs[r0..r1]);
            stream.extend_from_slice(&words);
            slice_offsets.push(stream.len() as u32);
        }

        Ok(CsrDtans {
            params: p,
            precision: prec,
            delta_encode: opts.delta_encode,
            nrows: csr.nrows,
            ncols: csr.ncols,
            nnz: csr.nnz(),
            delta_domain,
            value_domain,
            delta_tables,
            value_tables,
            row_nnz: (0..csr.nrows).map(|r| csr.row_len(r) as u32).collect(),
            slice_offsets,
            stream,
            delta_escapes,
            value_escapes,
            delta_esc_offsets,
            value_esc_offsets,
        })
    }

    /// Replay the warp-synchronous decode of one slice, invoking
    /// `emit(row, col, value)` for every nonzero (in per-lane column order).
    ///
    /// This is the CUDA kernel's control flow executed in lockstep on the
    /// CPU: one shared stream cursor, per-event lane ranks, per-lane
    /// decoder state — see `spmv::csr_dtans` for the fused SpMVM variant.
    pub fn walk_slice<F: FnMut(usize, u32, f64)>(&self, slice: usize, mut emit: F) -> Result<()> {
        let p = &self.params;
        let (l, o, f) = (p.l as usize, p.o as usize, p.f as usize);
        let nps = self.nnz_per_segment();
        let r0 = slice * WARP;
        let r1 = (r0 + WARP).min(self.nrows);
        let lanes = r1 - r0;
        let stream = &self.stream
            [self.slice_offsets[slice] as usize..self.slice_offsets[slice + 1] as usize];
        let mut pos = 0usize;
        let load = |pos: &mut usize| -> Result<u32> {
            let w = *stream
                .get(*pos)
                .ok_or_else(|| DtansError::CorruptStream("slice stream exhausted".into()))?;
            *pos += 1;
            Ok(w)
        };

        let tabs = [&self.delta_tables, &self.value_tables];
        let mut dec: Vec<RowDecoder> = (0..lanes)
            .map(|i| RowDecoder::new(*p, self.row_segments(r0 + i) * l))
            .collect::<Result<_>>()?;
        // Per-lane progress state.
        let mut emitted = vec![0usize; lanes];
        let mut col_acc = vec![0u32; lanes];
        let mut esc_d: Vec<usize> = (0..lanes)
            .map(|i| self.delta_esc_offsets[r0 + i] as usize)
            .collect();
        let mut esc_v: Vec<usize> = (0..lanes)
            .map(|i| self.value_esc_offsets[r0 + i] as usize)
            .collect();
        let mut sym_buf = vec![0u16; l];

        // Initial o words for non-empty lanes.
        for k in 0..o {
            for lane in 0..lanes {
                if dec[lane].nseg() > 0 {
                    let w = load(&mut pos)?;
                    dec[lane].supply(k, w);
                }
            }
        }
        let max_seg = (0..lanes).map(|i| dec[i].nseg()).max().unwrap_or(0);
        for _t in 0..max_seg {
            // Decode the current segment of each active lane.
            for lane in 0..lanes {
                if !dec[lane].active() {
                    continue;
                }
                dec[lane].begin_segment(&tabs, &mut sym_buf);
                let row = r0 + lane;
                let nnz_r = self.row_nnz[row] as usize;
                for i in 0..nps {
                    if emitted[lane] >= nnz_r {
                        break; // padding
                    }
                    let ds = sym_buf[2 * i];
                    let vs = sym_buf[2 * i + 1];
                    let d = if self.delta_domain.escaped(ds) {
                        let v = self.delta_escapes[esc_d[lane]];
                        esc_d[lane] += 1;
                        v
                    } else {
                        self.delta_domain.payload_of(ds) as u32
                    };
                    let vp = if self.value_domain.escaped(vs) {
                        let v = self.value_escapes[esc_v[lane]];
                        esc_v[lane] += 1;
                        v
                    } else {
                        self.value_domain.payload_of(vs)
                    };
                    let col = if emitted[lane] == 0 || !self.delta_encode {
                        d
                    } else {
                        col_acc[lane] + d
                    };
                    col_acc[lane] = col;
                    emitted[lane] += 1;
                    emit(row, col, value_from_payload(vp, self.precision));
                }
            }
            // Produce next-segment words: checks then unconditional loads,
            // each a warp-wide event over the producing lanes.
            for g in 0..f {
                for lane in 0..lanes {
                    if dec[lane].active() && dec[lane].producing() {
                        dec[lane].push_group(&tabs, g);
                        if !dec[lane].check(g) {
                            let w = load(&mut pos)?;
                            dec[lane].supply(g, w);
                        }
                    }
                }
            }
            for k in f..o {
                for lane in 0..lanes {
                    if dec[lane].active() && dec[lane].producing() {
                        let w = load(&mut pos)?;
                        dec[lane].supply(k, w);
                    }
                }
            }
            for lane in 0..lanes {
                if dec[lane].active() {
                    dec[lane].end_segment();
                }
            }
        }
        if pos != stream.len() {
            return Err(DtansError::CorruptStream(format!(
                "slice {slice}: {} of {} words consumed",
                pos,
                stream.len()
            )));
        }
        Ok(())
    }

    /// Replay all slices.
    pub fn walk<F: FnMut(usize, u32, f64)>(&self, mut emit: F) -> Result<()> {
        for s in 0..self.nslices() {
            self.walk_slice(s, &mut emit)?;
        }
        Ok(())
    }

    /// Full inverse transform back to CSR (order within rows is by column,
    /// as encoded).
    pub fn decode_to_csr(&self) -> Result<Csr> {
        let mut coo = crate::matrix::coo::Coo::new(self.nrows, self.ncols);
        self.walk(|r, c, v| coo.push(r as u32, c, v))?;
        Ok(Csr::from_coo(&coo))
    }

    /// Byte-size breakdown (see `SizeReport`).
    pub fn size_report(&self) -> SizeReport {
        let vb = self.precision.value_bytes();
        let mut s = SizeReport {
            header: 64,
            tables: self.delta_tables.table_bytes() + self.value_tables.table_bytes(),
            dicts: self.delta_domain.num_symbols() * 4 + self.value_domain.num_symbols() * vb,
            stream: self.stream.len() * 4,
            row_lens: self.row_nnz.len() * 4,
            slice_offsets: self.slice_offsets.len() * 4,
            escapes: self.delta_escapes.len() * 4 + self.value_escapes.len() * vb,
            escape_offsets: 0,
            total: 0,
        };
        if !self.delta_escapes.is_empty() {
            s.escape_offsets += self.delta_esc_offsets.len() * 4;
        }
        if !self.value_escapes.is_empty() {
            s.escape_offsets += self.value_esc_offsets.len() * 4;
        }
        s.total = s.header
            + s.tables
            + s.dicts
            + s.stream
            + s.row_lens
            + s.slice_offsets
            + s.escapes
            + s.escape_offsets;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
    use crate::matrix::gen::structured::{banded, powerlaw_rows, tridiagonal};
    use crate::util::rng::Xoshiro256;

    fn roundtrip(csr: &Csr, opts: &EncodeOptions) -> CsrDtans {
        let enc = CsrDtans::encode(csr, opts).unwrap();
        let back = enc.decode_to_csr().unwrap();
        let want = match opts.precision {
            Precision::F64 => csr.clone(),
            Precision::F32 => csr.round_to_f32(),
        };
        assert_eq!(back.row_ptr, want.row_ptr);
        assert_eq!(back.cols, want.cols);
        assert_eq!(back.vals, want.vals);
        enc
    }

    #[test]
    fn tridiagonal_roundtrip_and_compresses() {
        let m = tridiagonal(500);
        let enc = roundtrip(&m, &EncodeOptions::default());
        let rep = enc.size_report();
        assert_eq!(rep.total, rep.header + rep.tables + rep.dicts + rep.stream
            + rep.row_lens + rep.slice_offsets + rep.escapes + rep.escape_offsets);
        // Highly structured: stream alone must be far below CSR payload.
        assert!(rep.stream < m.nnz() * 6, "stream {} nnz {}", rep.stream, m.nnz());
    }

    #[test]
    fn graph_roundtrip_f64_and_f32() {
        let mut rng = Xoshiro256::seeded(3);
        let mut m = gen_graph_csr(GraphModel::ErdosRenyi, 700, 8.0, &mut rng);
        assign_values(&mut m, ValueDist::FewDistinct(12), &mut rng);
        roundtrip(&m, &EncodeOptions::default());
        roundtrip(
            &m,
            &EncodeOptions {
                precision: Precision::F32,
                ..Default::default()
            },
        );
    }

    #[test]
    fn kernel_params_roundtrip() {
        let mut rng = Xoshiro256::seeded(4);
        let mut m = gen_graph_csr(GraphModel::BarabasiAlbert, 300, 6.0, &mut rng);
        assign_values(&mut m, ValueDist::Quantized(64), &mut rng);
        roundtrip(
            &m,
            &EncodeOptions {
                params: AnsParams::KERNEL,
                ..Default::default()
            },
        );
    }

    #[test]
    fn random_values_escape_heavy_roundtrip() {
        let mut rng = Xoshiro256::seeded(5);
        let mut m = banded(300, 4);
        assign_values(&mut m, ValueDist::Random, &mut rng);
        let enc = roundtrip(&m, &EncodeOptions::default());
        // Nearly every value must have escaped.
        assert!(enc.value_escapes.len() > m.nnz() * 9 / 10);
    }

    #[test]
    fn irregular_rows_roundtrip() {
        let mut rng = Xoshiro256::seeded(6);
        let mut m = powerlaw_rows(300, 6.0, 1.2, &mut rng);
        assign_values(&mut m, ValueDist::Ones, &mut rng);
        roundtrip(&m, &EncodeOptions::default());
        roundtrip(
            &m,
            &EncodeOptions {
                params: AnsParams::KERNEL,
                ..Default::default()
            },
        );
    }

    #[test]
    fn empty_and_tiny_matrices() {
        roundtrip(&Csr::new(0, 0), &EncodeOptions::default());
        roundtrip(&Csr::new(5, 5), &EncodeOptions::default());
        let mut coo = crate::matrix::coo::Coo::new(1, 1);
        coo.push(0, 0, 3.25);
        roundtrip(&Csr::from_coo(&coo), &EncodeOptions::default());
    }

    #[test]
    fn delta_encoding_off_roundtrip() {
        let m = tridiagonal(200);
        roundtrip(
            &m,
            &EncodeOptions {
                delta_encode: false,
                ..Default::default()
            },
        );
    }

    #[test]
    fn one_nnz_rows_cost_about_four_words() {
        // The paper's Fig. 6 "2x line" group: matrices with one nonzero per
        // row need ~4 words (1 for n + o=3 initial) per row.
        let n = 320;
        let mut coo = crate::matrix::coo::Coo::new(n, n);
        for i in 0..n {
            coo.push(i as u32, ((i * 7) % n) as u32, 1.0);
        }
        let m = Csr::from_coo(&coo);
        let enc = roundtrip(&m, &EncodeOptions::default());
        let rep = enc.size_report();
        let per_row = (rep.stream + rep.row_lens) as f64 / n as f64;
        assert!((per_row - 16.0).abs() < 1.0, "bytes/row {per_row}");
    }

    #[test]
    fn delta_encoding_shrinks_banded_stream() {
        let m = banded(2048, 8);
        let with = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let without = CsrDtans::encode(
            &m,
            &EncodeOptions {
                delta_encode: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            with.size_report().stream < without.size_report().stream,
            "with {} without {}",
            with.size_report().stream,
            without.size_report().stream
        );
    }
}
