//! Property-based tests (custom propcheck harness) on the invariants the
//! system's correctness rests on:
//!
//!  * dtANS row codec: roundtrip for arbitrary tables/symbols, stream-length
//!    accounting, bounded decoder state (d < r < W², the invariant proved
//!    in ans::dtans's module docs);
//!  * histogram normalization: sum/cap/feasibility;
//!  * CSR-dtANS: encode∘decode = id on random matrices, SpMVM matches CSR;
//!  * warp interleaving: schedule conservation (every word consumed once).

use dtans::ans::dtans::{decode_row, encode_row};
use dtans::ans::histogram::normalize_counts;
use dtans::ans::tables::CodingTables;
use dtans::ans::AnsParams;
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::coo::Coo;
use dtans::matrix::csr::Csr;
use dtans::matrix::Precision;
use dtans::util::propcheck::{check, Ctx};
use dtans::util::rng::Xoshiro256;

fn random_tables(p: &AnsParams, rng: &mut Xoshiro256, max_syms: usize) -> CodingTables {
    let min_syms = (p.k() as usize).div_ceil(p.m() as usize);
    let n = min_syms.max(2 + rng.below_usize(max_syms));
    // Heavy-tailed counts exercise both extract and load branches.
    let counts: Vec<u64> = (0..n).map(|i| 1 + 10_000 / (i as u64 + 1)).collect();
    CodingTables::build(p, &normalize_counts(&counts, p.k(), p.m()).unwrap()).unwrap()
}

#[test]
fn prop_row_roundtrip_both_presets() {
    for (name, p) in [("paper", AnsParams::PAPER), ("kernel", AnsParams::KERNEL)] {
        check(&format!("row-roundtrip-{name}"), 60, 30, |ctx: &mut Ctx| {
            let t0 = random_tables(&p, &mut ctx.rng, 200);
            let t1 = random_tables(&p, &mut ctx.rng, 500);
            let tabs = [&t0, &t1];
            let nseg = ctx.rng.below_usize(ctx.size + 1);
            let syms: Vec<u16> = (0..nseg * p.l as usize)
                .map(|i| {
                    let t = tabs[i % 2];
                    ctx.rng.below(t.num_symbols() as u64) as u16
                })
                .collect();
            let enc = encode_row(&p, &tabs, &syms).map_err(|e| e.to_string())?;
            let dec = decode_row(&p, &tabs, &enc.words, syms.len()).map_err(|e| e.to_string())?;
            if dec != syms {
                return Err("roundtrip mismatch".into());
            }
            // Stream length accounting: o initial + per non-final segment
            // (o - f) unconditional + one per load branch.
            if nseg > 0 {
                let loads = enc.branches.iter().filter(|&&b| !b).count();
                let expect =
                    p.o as usize + (nseg - 1) * (p.o - p.f) as usize + loads;
                if enc.words.len() != expect {
                    return Err(format!("stream len {} != {expect}", enc.words.len()));
                }
            }
            // Every word must be < W.
            if enc.words.iter().any(|&w| (w as u64) >= p.w()) {
                return Err("word exceeds radix".into());
            }
            Ok(())
        });
    }
}

#[test]
fn prop_normalization_invariants() {
    check("normalize-counts", 100, 300, |ctx: &mut Ctx| {
        let n = 1 + ctx.rng.below_usize(ctx.size.max(1));
        let k: u32 = 1 << (3 + ctx.rng.below_usize(10) as u32);
        let m_cap: u32 = 1 << (1 + ctx.rng.below_usize(8) as u32);
        let counts: Vec<u64> = (0..n).map(|_| 1 + ctx.rng.below(100_000)).collect();
        let cap = m_cap.min(k); // the cap actually passed below
        let feasible = n as u64 <= k as u64 && (n as u64) * (cap as u64) >= k as u64;
        match normalize_counts(&counts, k, cap) {
            Ok(mult) => {
                if !feasible {
                    return Err("accepted infeasible input".into());
                }
                if mult.iter().map(|&q| q as u64).sum::<u64>() != k as u64 {
                    return Err("sum != K".into());
                }
                if mult.iter().any(|&q| q == 0 || q > m_cap) {
                    return Err("multiplicity out of range".into());
                }
                Ok(())
            }
            Err(_) if !feasible => Ok(()),
            Err(e) => Err(format!("rejected feasible input: {e}")),
        }
    });
}

fn random_csr(ctx: &mut Ctx) -> Csr {
    let nrows = 1 + ctx.rng.below_usize(ctx.size.max(1));
    let ncols = 1 + ctx.rng.below_usize(ctx.size.max(1));
    let nnz = ctx.rng.below_usize(nrows * ncols.min(64) + 1);
    let mut coo = Coo::new(nrows, ncols);
    // Small value palette mixed with unique values exercises both the
    // dictionary and the escape path.
    for _ in 0..nnz {
        let v = if ctx.rng.chance(0.7) {
            (ctx.rng.below(4) as f64) - 1.5
        } else {
            ctx.rng.next_f64()
        };
        coo.push(
            ctx.rng.below_usize(nrows) as u32,
            ctx.rng.below_usize(ncols) as u32,
            v,
        );
    }
    Csr::from_coo(&coo)
}

#[test]
fn prop_format_roundtrip_random_matrices() {
    check("format-roundtrip", 40, 120, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let opts = if ctx.rng.chance(0.5) {
            EncodeOptions::default()
        } else {
            EncodeOptions {
                params: AnsParams::KERNEL,
                precision: if ctx.rng.chance(0.5) { Precision::F32 } else { Precision::F64 },
                delta_encode: ctx.rng.chance(0.8),
            }
        };
        let enc = CsrDtans::encode(&m, &opts).map_err(|e| e.to_string())?;
        let back = enc.decode_to_csr().map_err(|e| e.to_string())?;
        let want = match opts.precision {
            Precision::F64 => m.clone(),
            Precision::F32 => m.round_to_f32(),
        };
        if back != want {
            return Err("decode != encode input".into());
        }
        Ok(())
    });
}

#[test]
fn prop_spmv_matches_csr_random_matrices() {
    check("spmv-equivalence", 30, 100, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).map_err(|e| e.to_string())?;
        let x: Vec<f64> = (0..m.ncols).map(|_| ctx.rng.next_f64() - 0.5).collect();
        let mut want = vec![0.0; m.nrows];
        dtans::spmv::spmv_csr(&m, &x, &mut want).map_err(|e| e.to_string())?;
        let mut got = vec![0.0; m.nrows];
        dtans::spmv::spmv_csr_dtans(&enc, &x, &mut got).map_err(|e| e.to_string())?;
        dtans::util::propcheck::assert_close(&got, &want, 1e-10, 1e-12)
    });
}

#[test]
fn prop_corrupted_streams_never_panic() {
    // Fuzz the decoder: random mutations of a valid stream must either
    // decode (to something) or return an error — never panic or hang.
    check("corruption-safety", 40, 40, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let mut enc = CsrDtans::encode(&m, &EncodeOptions::default()).map_err(|e| e.to_string())?;
        if enc.stream.is_empty() {
            return Ok(());
        }
        for _ in 0..4 {
            let i = ctx.rng.below_usize(enc.stream.len());
            enc.stream[i] = ctx.rng.next_u32();
        }
        let x = vec![1.0; m.ncols];
        let mut y = vec![0.0; m.nrows];
        let _ = dtans::spmv::spmv_csr_dtans(&enc, &x, &mut y); // Ok or Err both fine
        Ok(())
    });
}
