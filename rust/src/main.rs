//! `dtans` CLI: generate/inspect matrices, encode/decode CSR-dtANS, run
//! SpMVM on the native or PJRT path, and regenerate every experiment of
//! the paper's evaluation.

use dtans::ans::AnsParams;
use dtans::eval::{ablate, fig4, fig6, fig9, runtime_experiment, tab1, CorpusScale};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::format::serialize;
use dtans::matrix::gen::structured::*;
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::matrix::stats::MatrixStats;
use dtans::matrix::{mtx, Csr, Precision, SizeModel};
use dtans::runtime::Runtime;
use dtans::spmv::{spmv_csr, spmv_csr_dtans};
use dtans::util::cli::Args;
use dtans::util::rng::Xoshiro256;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
dtans — entropy-coded sparse matrices with on-the-fly decoding SpMVM

USAGE: dtans <command> [options]

COMMANDS:
  gen --kind <tridiag|banded|stencil5|stencil27|er|ws|ba|powerlaw|random>
      --n <rows> [--deg <d>] [--values <ones|fewK|quantK|intsK|random|gaussian>]
      [--seed <s>] --out <file.mtx>          generate a matrix
  info <file.mtx>                            matrix + entropy statistics
  encode <file.mtx> --out <file.dtans>
      [--f32] [--kernel-params] [--no-delta] encode to CSR-dtANS
  decode <file.dtans> --out <file.mtx>       decode back to MatrixMarket
  spmv <file.mtx> [--pjrt] [--iters <n>]     run y = Ax (native or PJRT)
  exp <fig4|fig6|tab1|fig7|fig8|fig9|ablate|all>
      [--full] [--out results/]              regenerate paper experiments
  help                                       this text
";

fn main() {
    let args = Args::from_env(true);
    let code = match args.subcommand.as_deref() {
        Some("gen") => cmd_gen(&args),
        Some("info") => cmd_info(&args),
        Some("encode") => cmd_encode(&args),
        Some("decode") => cmd_decode(&args),
        Some("spmv") => cmd_spmv(&args),
        Some("exp") => cmd_exp(&args),
        Some("help") | None => {
            println!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn fail(e: impl std::fmt::Display) -> i32 {
    eprintln!("error: {e}");
    1
}

fn cmd_gen(args: &Args) -> i32 {
    let kind = args.get_or("kind", "er");
    let n = args.usize_or("n", 1024);
    let deg = args.f64_or("deg", 10.0);
    let seed = args.u64_or("seed", 42);
    let mut rng = Xoshiro256::seeded(seed);
    let mut m = match kind.as_str() {
        "tridiag" => tridiagonal(n),
        "banded" => banded(n, args.usize_or("bw", 4)),
        "stencil5" => {
            let s = (n as f64).sqrt() as usize;
            stencil2d5(s, s)
        }
        "stencil27" => {
            let s = (n as f64).cbrt() as usize;
            stencil3d27(s, s, s)
        }
        "er" => gen_graph_csr(GraphModel::ErdosRenyi, n, deg, &mut rng),
        "ws" => gen_graph_csr(GraphModel::WattsStrogatz, n, deg, &mut rng),
        "ba" => gen_graph_csr(GraphModel::BarabasiAlbert, n, deg, &mut rng),
        "powerlaw" => powerlaw_rows(n, deg, 1.1, &mut rng),
        "random" => random_uniform(n, n, (n as f64 * deg) as usize, &mut rng),
        other => return fail(format!("unknown kind {other:?}")),
    };
    if let Some(v) = args.get("values") {
        match ValueDist::parse(v) {
            Some(vd) => assign_values(&mut m, vd, &mut rng),
            None => return fail(format!("bad value distribution {v:?}")),
        }
    }
    let out = PathBuf::from(args.get_or("out", "matrix.mtx"));
    match mtx::save_mtx(&m, &out) {
        Ok(()) => {
            println!("wrote {} ({} x {}, {} nnz)", out.display(), m.nrows, m.ncols, m.nnz());
            0
        }
        Err(e) => fail(e),
    }
}

fn load_input(args: &Args) -> Result<Csr, i32> {
    let path = args.positional.first().ok_or_else(|| fail("missing input file"))?;
    mtx::load_mtx_csr(Path::new(path)).map_err(fail)
}

fn cmd_info(args: &Args) -> i32 {
    let m = match load_input(args) {
        Ok(m) => m,
        Err(c) => return c,
    };
    let s = MatrixStats::compute(&m);
    println!("shape        {} x {}", s.nrows, s.ncols);
    println!("nnz          {}", s.nnz);
    println!("annzpr       {:.2}", s.annzpr);
    println!("max row len  {}", s.max_row_len);
    println!("H(indices)   {:.3} bits", s.h_indices);
    println!("H(deltas)    {:.3} bits  (ratio {:.3})", s.h_deltas, s.relative_delta_entropy());
    println!("H(values)    {:.3} bits  ({} distinct)", s.h_values, s.distinct_values);
    for prec in [Precision::F64, Precision::F32] {
        let model = SizeModel { precision: prec };
        let (bytes, fmt) = model.best_baseline_bytes(&m);
        let enc = CsrDtans::encode(
            &m,
            &EncodeOptions {
                precision: prec,
                ..Default::default()
            },
        )
        .expect("encode");
        let r = enc.size_report();
        println!(
            "{}: best cuSPARSE-format {} = {} B; CSR-dtANS = {} B (ratio {:.2}x)",
            prec.label(),
            fmt,
            bytes,
            r.total,
            bytes as f64 / r.total as f64
        );
    }
    0
}

fn encode_opts(args: &Args) -> EncodeOptions {
    EncodeOptions {
        params: if args.flag("kernel-params") {
            AnsParams::KERNEL
        } else {
            AnsParams::PAPER
        },
        precision: if args.flag("f32") { Precision::F32 } else { Precision::F64 },
        delta_encode: !args.flag("no-delta"),
    }
}

fn cmd_encode(args: &Args) -> i32 {
    let m = match load_input(args) {
        Ok(m) => m,
        Err(c) => return c,
    };
    let opts = encode_opts(args);
    let enc = match CsrDtans::encode(&m, &opts) {
        Ok(e) => e,
        Err(e) => return fail(e),
    };
    let r = enc.size_report();
    println!(
        "encoded: total {} B (tables {} + dicts {} + stream {} + row_lens {} + escapes {})",
        r.total, r.tables, r.dicts, r.stream, r.row_lens, r.escapes
    );
    let out = PathBuf::from(args.get_or("out", "matrix.dtans"));
    match serialize::save(&enc, &out) {
        Ok(()) => {
            println!("wrote {}", out.display());
            0
        }
        Err(e) => fail(e),
    }
}

fn cmd_decode(args: &Args) -> i32 {
    let path = match args.positional.first() {
        Some(p) => p.clone(),
        None => return fail("missing input file"),
    };
    let enc = match serialize::load(Path::new(&path)) {
        Ok(e) => e,
        Err(e) => return fail(e),
    };
    let m = match enc.decode_to_csr() {
        Ok(m) => m,
        Err(e) => return fail(e),
    };
    let out = PathBuf::from(args.get_or("out", "decoded.mtx"));
    match mtx::save_mtx(&m, &out) {
        Ok(()) => {
            println!("wrote {} ({} nnz)", out.display(), m.nnz());
            0
        }
        Err(e) => fail(e),
    }
}

fn cmd_spmv(args: &Args) -> i32 {
    let m = match load_input(args) {
        Ok(m) => m,
        Err(c) => return c,
    };
    let iters = args.usize_or("iters", 10);
    let mut rng = Xoshiro256::seeded(7);
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
    let mut want = vec![0.0; m.nrows];
    if let Err(e) = spmv_csr(&m, &x, &mut want) {
        return fail(e);
    }
    if args.flag("pjrt") {
        let rt = match Runtime::open(&Runtime::default_dir()) {
            Ok(r) => r,
            Err(e) => return fail(e),
        };
        let opts = EncodeOptions {
            params: AnsParams::KERNEL,
            precision: Precision::F32,
            delta_encode: true,
        };
        let enc = match CsrDtans::encode(&m, &opts) {
            Ok(e) => e,
            Err(e) => return fail(e),
        };
        let y_in = vec![0.0; m.nrows];
        let t0 = std::time::Instant::now();
        let mut y = Vec::new();
        for _ in 0..iters {
            y = match rt.spmv_dtans(&enc, &x, &y_in) {
                Ok(y) => y,
                Err(e) => return fail(e),
            };
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        let err = (0..m.nrows)
            .map(|r| (want[r] - y[r] as f64).abs())
            .fold(0.0f64, f64::max);
        println!("pjrt spmv: {:.3} ms/iter, max |err| vs CSR = {err:.2e}", dt * 1e3);
    } else {
        let enc = match CsrDtans::encode(&m, &encode_opts(args)) {
            Ok(e) => e,
            Err(e) => return fail(e),
        };
        let t0 = std::time::Instant::now();
        let mut y = vec![0.0; m.nrows];
        for _ in 0..iters {
            y.iter_mut().for_each(|v| *v = 0.0);
            if let Err(e) = spmv_csr_dtans(&enc, &x, &mut y) {
                return fail(e);
            }
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        let err = (0..m.nrows)
            .map(|r| (want[r] - y[r]).abs())
            .fold(0.0f64, f64::max);
        let gbps = (enc.size_report().total as f64 / dt) / 1e9;
        println!(
            "native spmv: {:.3} ms/iter ({:.2} GB/s decoded), max |err| vs CSR = {err:.2e}",
            dt * 1e3,
            gbps
        );
    }
    0
}

fn cmd_exp(args: &Args) -> i32 {
    let which = args.positional.first().cloned().unwrap_or_else(|| "all".into());
    let scale = if args.flag("full") {
        CorpusScale::default()
    } else {
        CorpusScale {
            max_nnz: 1 << 18,
            steps: 5,
        }
    };
    let outdir = PathBuf::from(args.get_or("out", "results"));
    let run = |name: &str| -> Option<dtans::eval::ExperimentOutput> {
        match name {
            "fig4" => Some(fig4(if args.flag("full") { 1 << 17 } else { 1 << 14 })),
            "fig6" => Some(fig6(&scale)),
            "tab1" => Some(tab1(&scale)),
            "fig7" => Some(runtime_experiment(&scale, true)),
            "fig8" => Some(runtime_experiment(&scale, false)),
            "fig9" => Some(fig9(&scale)),
            "ablate" => Some(ablate(&scale)),
            _ => None,
        }
    };
    let names: Vec<&str> = if which == "all" {
        vec!["fig4", "fig6", "tab1", "fig7", "fig8", "fig9", "ablate"]
    } else {
        vec![which.as_str()]
    };
    for name in names {
        let t0 = std::time::Instant::now();
        match run(name) {
            Some(out) => match dtans::eval::report::save(&out, &outdir) {
                Ok(summary) => {
                    println!("== {name} ({:.1}s) ==", t0.elapsed().as_secs_f64());
                    println!("{summary}");
                    for (stem, t) in &out.tables {
                        if t.rows.len() <= 12 {
                            println!("{}", t.to_markdown());
                        } else {
                            println!("[{} rows -> {}/{stem}.csv]", t.rows.len(), outdir.display());
                        }
                    }
                }
                Err(e) => return fail(e),
            },
            None => return fail(format!("unknown experiment {name:?}")),
        }
    }
    0
}
