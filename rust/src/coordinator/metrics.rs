//! Service metrics: request counters, store counters, and latency
//! quantiles over fixed-size sliding-window reservoirs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Samples retained per reservoir.
const RESERVOIR_CAP: usize = 65536;

/// Fixed-size ring of the most recent [`RESERVOIR_CAP`] samples. Unlike
/// the old grow-then-drain reservoir (which discarded the oldest 32k
/// samples *wholesale* at 64k, so quantiles right after a drain were
/// computed over a recent-burst-only window), the ring retires exactly
/// one oldest sample per new sample — the window slides, it never jumps.
#[derive(Debug, Default)]
struct Ring {
    buf: Vec<u64>,
    /// Oldest slot, once the ring is full.
    next: usize,
}

impl Ring {
    fn push(&mut self, v: u64) {
        if self.buf.len() < RESERVOIR_CAP {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % RESERVOIR_CAP;
        }
    }
}

/// Lock-free counters + mutexed latency reservoirs.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Registrations served from the on-disk artifact cache (encode
    /// skipped).
    pub store_hits: AtomicU64,
    /// Registrations that had to encode.
    pub store_misses: AtomicU64,
    /// Matrices evicted from residency by the byte budget.
    pub evictions: AtomicU64,
    /// Background artifact persists that failed (the matrix stays
    /// resident and unevictable — the budget cannot be enforced for it).
    pub persist_failures: AtomicU64,
    /// Cold loads (evicted matrices faulted back in from disk).
    pub cold_loads: AtomicU64,
    latencies_us: Mutex<Ring>,
    cold_load_us: Mutex<Ring>,
}

/// Quantile summary of a latency reservoir.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    /// Number of samples.
    pub count: usize,
    /// 50th percentile, microseconds.
    pub p50_us: u64,
    /// 99th percentile, microseconds.
    pub p99_us: u64,
    /// Maximum, microseconds.
    pub max_us: u64,
}

impl LatencySummary {
    /// Summarize raw samples (sorts in place).
    fn from_samples(mut l: Vec<u64>) -> LatencySummary {
        if l.is_empty() {
            return LatencySummary::default();
        }
        l.sort_unstable();
        let q = |p: f64| l[((l.len() - 1) as f64 * p) as usize];
        LatencySummary {
            count: l.len(),
            p50_us: q(0.50),
            p99_us: q(0.99),
            max_us: *l.last().unwrap(),
        }
    }
}

impl Metrics {
    /// Record one completed request's latency.
    pub fn record_latency(&self, micros: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(micros);
    }

    /// Record one cold load (store fault-in) latency.
    pub fn record_cold_load(&self, micros: u64) {
        self.cold_loads.fetch_add(1, Ordering::Relaxed);
        self.cold_load_us.lock().unwrap().push(micros);
    }

    /// Quantile summary over the request-latency window.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary::from_samples(self.latencies_us.lock().unwrap().buf.clone())
    }

    /// Quantile summary over the cold-load-latency window.
    pub fn cold_load_summary(&self) -> LatencySummary {
        LatencySummary::from_samples(self.cold_load_us.lock().unwrap().buf.clone())
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let s = self.latency_summary();
        let c = self.cold_load_summary();
        format!(
            "submitted={} completed={} failed={} batches={} p50={}µs p99={}µs max={}µs \
             store_hits={} store_misses={} evictions={} persist_failures={} cold_loads={} \
             cold_p50={}µs cold_p99={}µs",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            s.p50_us,
            s.p99_us,
            s.max_us,
            self.store_hits.load(Ordering::Relaxed),
            self.store_misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
            self.persist_failures.load(Ordering::Relaxed),
            self.cold_loads.load(Ordering::Relaxed),
            c.p50_us,
            c.p99_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 100);
        assert!((49..=51).contains(&s.p50_us));
        assert!(s.p99_us >= 98);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_summary() {
        let m = Metrics::default();
        assert_eq!(m.latency_summary().count, 0);
        assert!(m.report().contains("submitted=0"));
    }

    #[test]
    fn ring_slides_one_sample_at_a_time() {
        let m = Metrics::default();
        let n = RESERVOIR_CAP + 1000;
        for i in 0..n {
            m.record_latency(i as u64);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, RESERVOIR_CAP);
        // Window is exactly the most recent CAP samples: [1000, n).
        assert_eq!(s.max_us, (n - 1) as u64);
        assert!(s.p50_us >= 1000);
        // The median sits mid-window — the old drain-half behavior would
        // have put it deep in the recent half right after a drain.
        let mid = 1000 + RESERVOIR_CAP as u64 / 2;
        assert!(
            (s.p50_us as i64 - mid as i64).abs() <= 1,
            "p50 {} not centered on {mid}",
            s.p50_us
        );
        assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn cold_load_reservoir_is_independent() {
        let m = Metrics::default();
        m.record_latency(10);
        m.record_cold_load(5000);
        m.record_cold_load(7000);
        assert_eq!(m.latency_summary().count, 1);
        let c = m.cold_load_summary();
        assert_eq!(c.count, 2);
        assert_eq!(c.max_us, 7000);
        assert_eq!(m.cold_loads.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("cold_loads=2"));
    }
}
