//! Parallel nnz-balanced SpMVM engine over the format-agnostic
//! [`SpmvOperator`] trait.
//!
//! The paper's GPU kernel assigns one warp per 32-row slice and wins
//! because SpMVM is bandwidth-bound; the CPU reproduction was leaving that
//! same parallelism on the table by running every kernel single-threaded.
//! This engine closes the gap: an nnz-balanced partitioner
//! ([`partition_prefix`], binary search over cost prefixes — the CPU
//! analog of the paper's warp work assignment) plus a scoped executor that
//! fans blocks out across a [`ThreadPool`], handing each worker a disjoint
//! `&mut` range of the output vector.
//!
//! The engine is **format-agnostic**: [`SpmvEngine::run`] and
//! [`SpmvEngine::run_multi`] accept any `&dyn SpmvOperator` — the
//! operator describes its work units via
//! [`cost_prefix`](SpmvOperator::cost_prefix) and computes blocks via
//! [`run_range`](SpmvOperator::run_range); the engine owns scheduling.
//! (The old per-format `spmv_csr`/`spmv_sell`/`spmm_*` methods are gone;
//! see `docs/API.md` for the migration table.)
//!
//! Because blocks are contiguous and every row is computed by exactly one
//! block with the serial kernel's per-row arithmetic, parallel results are
//! **bit-identical** to the serial free functions for every built-in
//! format — property-tested in `tests/operator_dispatch.rs` across
//! partition counts 1..=16.
//!
//! # Strategy selection ([`ParStrategy`])
//!
//! * [`ParStrategy::Serial`] — always run on the calling thread; no pool
//!   is created. Use when the caller manages parallelism itself (e.g. the
//!   evaluation harness that already parallelizes across matrices) or for
//!   exact control in tests.
//! * [`ParStrategy::Fixed(n)`](ParStrategy::Fixed) — always fan out across
//!   `n` blocks on `n` worker threads, even for tiny inputs. Use for
//!   scaling studies and reproducible partition counts; `Fixed(1)` is the
//!   serial path (no pool is spawned).
//! * [`ParStrategy::Auto`] (default) — one block per logical CPU, but fall
//!   back to the serial path whenever the estimated work
//!   ([`SpmvOperator::cost`], times right-hand sides for the batched
//!   entry point) is below [`MIN_PAR_COST`], where fan-out overhead
//!   would dominate. This is the right default for services.
//!
//! # Example
//!
//! ```
//! use dtans::matrix::gen::structured::banded;
//! use dtans::matrix::gen::{assign_values, ValueDist};
//! use dtans::spmv::engine::{ParStrategy, SpmvEngine};
//! use dtans::spmv::spmv_csr;
//! use dtans::util::rng::Xoshiro256;
//!
//! let mut m = banded(1000, 3);
//! assign_values(&mut m, ValueDist::FewDistinct(8), &mut Xoshiro256::seeded(1));
//! let x = vec![1.0; m.ncols];
//!
//! let engine = SpmvEngine::new(ParStrategy::Fixed(4));
//! let mut y_par = vec![0.0; m.nrows];
//! engine.run(&m, &x, &mut y_par).unwrap(); // Csr is an SpmvOperator
//!
//! let mut y_serial = vec![0.0; m.nrows];
//! spmv_csr(&m, &x, &mut y_serial).unwrap();
//! assert_eq!(y_par, y_serial); // bit-identical, not merely close
//! ```

pub mod partition;

pub use partition::{partition_prefix, Block};

use crate::spmv::densemat::DenseMat;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::{DtansError, Result};
use crate::util::threadpool::{ScopedJob, ThreadPool};

/// Below this many "cost units" ([`SpmvOperator::cost`] × right-hand
/// sides — calibrated in nonzeros), the [`ParStrategy::Auto`] strategy
/// runs serially: fanning a multiply this small across threads costs
/// more in wake-ups than the multiply itself.
pub const MIN_PAR_COST: usize = 1 << 14;

/// Which per-row accumulation the engine asks operators to run — the
/// kernel-variant knob of the SIMD speed push (`docs/KERNELS.md`).
///
/// * [`KernelVariant::Scalar`] (default) — the serial left-to-right
///   kernels; results bit-identical to the free functions, as before.
/// * [`KernelVariant::Unrolled4`] / [`KernelVariant::Unrolled8`] — the
///   hand-unrolled wide-accumulator kernels
///   ([`crate::spmv::unrolled`]) with a fixed lane count and combine
///   tree. For a fixed variant, results are still **bit-identical across
///   every [`ParStrategy`] and partition count** (the lane assignment
///   depends only on within-row element positions, never on block
///   boundaries); across variants they differ by float reassociation,
///   within the conformance oracle's closeness bound.
///
/// Formats without unrolled kernels (COO's scatter, the dtANS lockstep
/// decoder, the dense oracle) ignore the knob and always run their scalar
/// kernels — the trait's default [`SpmvOperator::run_range_variant`]
/// delegates to [`SpmvOperator::run_range`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelVariant {
    /// Serial left-to-right accumulation (the free-function kernels).
    #[default]
    Scalar,
    /// 4-wide lane-strided accumulation with the fixed combine tree.
    Unrolled4,
    /// 8-wide lane-strided accumulation with the fixed combine tree.
    Unrolled8,
}

impl KernelVariant {
    /// Every variant, in sweep order — what the conformance oracle's
    /// `cross_check_with` iterates.
    pub const ALL: [KernelVariant; 3] =
        [KernelVariant::Scalar, KernelVariant::Unrolled4, KernelVariant::Unrolled8];

    /// Stable short label (`"scalar"`, `"unrolled4"`, `"unrolled8"`) for
    /// reports and bench rows.
    pub fn label(&self) -> &'static str {
        match self {
            KernelVariant::Scalar => "scalar",
            KernelVariant::Unrolled4 => "unrolled4",
            KernelVariant::Unrolled8 => "unrolled8",
        }
    }
}

/// How the engine maps one multiply onto threads; see the
/// [module docs](self) for selection rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParStrategy {
    /// Always run on the calling thread.
    Serial,
    /// Always fan out across exactly this many nnz-balanced blocks.
    Fixed(usize),
    /// One block per logical CPU; serial below [`MIN_PAR_COST`].
    #[default]
    Auto,
}

/// Per-block wall-time spread of one timed engine call
/// ([`SpmvEngine::run_timed`]): the partition-imbalance signal the
/// observability layer records (`blk_imb` in
/// [`Metrics::report`](crate::coordinator::metrics::Metrics::report)) and
/// the adaptive-routing / SIMD roadmap items consume. A serial call
/// reports one block with `min == max == mean`.
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockTiming {
    /// Blocks the call fanned out into (1 = serial path).
    pub blocks: usize,
    /// Fastest block, microseconds.
    pub min_us: u64,
    /// Slowest block, microseconds (the straggler that bounds the call).
    pub max_us: u64,
    /// Mean block, microseconds (`max/mean` ≫ 1 ⇒ imbalanced partition).
    pub mean_us: u64,
}

impl BlockTiming {
    /// Aggregate per-block micros into the summary.
    fn from_times(times_us: &[u64]) -> BlockTiming {
        if times_us.is_empty() {
            return BlockTiming::default();
        }
        let sum: u64 = times_us.iter().sum();
        BlockTiming {
            blocks: times_us.len(),
            min_us: *times_us.iter().min().unwrap(),
            max_us: *times_us.iter().max().unwrap(),
            mean_us: sum / times_us.len() as u64,
        }
    }
}

/// The parallel SpMVM engine: owns a worker pool and routes any
/// [`SpmvOperator`] through the nnz-balanced partitioner. See the
/// [module docs](self) for the execution model.
///
/// The engine is `Sync`: one instance can be shared by many request
/// threads (the coordinator does exactly this), with each call waiting
/// only on its own blocks.
pub struct SpmvEngine {
    strategy: ParStrategy,
    nthreads: usize,
    pool: Option<ThreadPool>,
    variant: KernelVariant,
}

impl Default for SpmvEngine {
    fn default() -> Self {
        SpmvEngine::new(ParStrategy::Auto)
    }
}

impl SpmvEngine {
    /// Build an engine with the given strategy (spawns the worker pool
    /// unless the strategy is [`ParStrategy::Serial`]).
    pub fn new(strategy: ParStrategy) -> SpmvEngine {
        let nthreads = match strategy {
            ParStrategy::Serial => 1,
            ParStrategy::Fixed(n) => n.max(1),
            ParStrategy::Auto => ThreadPool::default_parallelism(),
        };
        let pool = match strategy {
            ParStrategy::Serial => None,
            _ if nthreads < 2 => None,
            _ => Some(ThreadPool::new(nthreads)),
        };
        SpmvEngine { strategy, nthreads, pool, variant: KernelVariant::default() }
    }

    /// Engine that always runs on the calling thread.
    pub fn serial() -> SpmvEngine {
        SpmvEngine::new(ParStrategy::Serial)
    }

    /// Engine with the [`ParStrategy::Auto`] policy (the default).
    pub fn auto() -> SpmvEngine {
        SpmvEngine::new(ParStrategy::Auto)
    }

    /// Builder: select the per-row accumulation every multiply on this
    /// engine runs with (default [`KernelVariant::Scalar`]). For a fixed
    /// variant, results stay bit-identical across all strategies and
    /// partition counts — see [`KernelVariant`].
    pub fn with_kernel_variant(mut self, variant: KernelVariant) -> SpmvEngine {
        self.variant = variant;
        self
    }

    /// The configured strategy.
    pub fn strategy(&self) -> ParStrategy {
        self.strategy
    }

    /// The configured kernel variant.
    pub fn kernel_variant(&self) -> KernelVariant {
        self.variant
    }

    /// Worker threads available to this engine (1 for serial).
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// True when this engine owns a worker pool and can fan a multiply
    /// out (false for [`ParStrategy::Serial`] and single-thread configs).
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// True when a batched call over an operator with total cost `cost`
    /// and `k` right-hand sides would actually fan out (callers with
    /// their own request-level parallelism — the coordinator's worker
    /// pool — use this to decide whether handing the whole batch to the
    /// engine beats per-request dispatch). Nonzeros are a fine proxy for
    /// `cost` when the exact prefix total is not at hand.
    pub fn will_batch_parallel(&self, cost: usize, k: usize) -> bool {
        self.pool.is_some() && self.batch_parts(cost, k).is_some()
    }

    /// Number of blocks a multiply of the given cost will fan out into;
    /// 1 means the serial path.
    fn parts_for(&self, cost: usize) -> usize {
        match self.strategy {
            ParStrategy::Serial => 1,
            ParStrategy::Fixed(n) => n.max(1),
            ParStrategy::Auto => {
                if cost < MIN_PAR_COST || self.nthreads < 2 {
                    1
                } else {
                    self.nthreads
                }
            }
        }
    }

    /// `y += A·x` for any [`SpmvOperator`], partitioned into equal-cost
    /// blocks from the operator's [`cost_prefix`](SpmvOperator::cost_prefix).
    /// Bit-identical to the format's serial free function.
    ///
    /// ```
    /// use dtans::matrix::{Coo, Csr};
    /// use dtans::spmv::engine::SpmvEngine;
    /// let mut coo = Coo::new(2, 2);
    /// coo.push(0, 0, 2.0);
    /// coo.push(1, 1, 3.0);
    /// let m = Csr::from_coo(&coo);
    /// let mut y = vec![0.0; 2];
    /// SpmvEngine::auto().run(&m, &[1.0, 1.0], &mut y).unwrap();
    /// assert_eq!(y, vec![2.0, 3.0]);
    /// ```
    pub fn run(&self, op: &dyn SpmvOperator, x: &[f64], y: &mut [f64]) -> Result<()> {
        self.run_variant(op, x, y, self.variant)
    }

    /// [`SpmvEngine::run`] with a per-call kernel-variant override: same
    /// partitioning and arithmetic, but every block executes `variant`
    /// instead of the engine's configured default. The adaptive router
    /// ([`crate::coordinator::adaptive`]) uses this to steer individual
    /// requests onto challenger variants without rebuilding engines (and
    /// without perturbing concurrent requests on the default route).
    pub fn run_variant(
        &self,
        op: &dyn SpmvOperator,
        x: &[f64],
        y: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        let (nrows, ncols) = op.dims();
        crate::spmv::check_dims(nrows, ncols, x, y)?;
        let prefix = op.cost_prefix();
        let (units, total) = prefix_stats(&prefix);
        let parts = self.parts_for(op.cost());
        match &self.pool {
            Some(pool) if parts > 1 && units > 1 => {
                let blocks = partition_prefix(&prefix, parts);
                run_blocks(
                    pool,
                    &blocks,
                    y,
                    |b| op.rows_through(b.end),
                    |b, seg| op.run_range_variant(b, x, seg, variant),
                )
            }
            _ => op.run_range_variant(
                Block { start: 0, end: units, cost: total },
                x,
                y,
                variant,
            ),
        }
    }

    /// [`SpmvEngine::run`] with a per-block timing hook: identical
    /// partitioning and arithmetic (results stay **bit-identical** to
    /// [`SpmvEngine::run`] — each block's kernel is merely bracketed by
    /// two clock reads), returning the per-block wall-time spread. This
    /// is the optional instrumentation path: the coordinator uses it when
    /// tracing is enabled and falls back to the unbracketed `run`
    /// otherwise, so the hot path pays nothing when observability is off.
    pub fn run_timed(
        &self,
        op: &dyn SpmvOperator,
        x: &[f64],
        y: &mut [f64],
    ) -> Result<BlockTiming> {
        self.run_timed_variant(op, x, y, self.variant)
    }

    /// [`SpmvEngine::run_timed`] with a per-call kernel-variant override
    /// (see [`SpmvEngine::run_variant`]). The adaptive router's feedback
    /// loop runs this so the latency it learns from is measured on the
    /// exact arm it routed to.
    pub fn run_timed_variant(
        &self,
        op: &dyn SpmvOperator,
        x: &[f64],
        y: &mut [f64],
        variant: KernelVariant,
    ) -> Result<BlockTiming> {
        let (nrows, ncols) = op.dims();
        crate::spmv::check_dims(nrows, ncols, x, y)?;
        let prefix = op.cost_prefix();
        let (units, total) = prefix_stats(&prefix);
        let parts = self.parts_for(op.cost());
        match &self.pool {
            Some(pool) if parts > 1 && units > 1 => {
                let blocks = partition_prefix(&prefix, parts);
                let mut times_us = vec![0u64; blocks.len()];
                run_blocks_timed(
                    pool,
                    &blocks,
                    y,
                    &mut times_us,
                    |b| op.rows_through(b.end),
                    |b, seg| op.run_range_variant(b, x, seg, variant),
                )?;
                Ok(BlockTiming::from_times(&times_us))
            }
            _ => {
                let t0 = std::time::Instant::now();
                op.run_range_variant(
                    Block { start: 0, end: units, cost: total },
                    x,
                    y,
                    variant,
                )?;
                let us = t0.elapsed().as_micros() as u64;
                Ok(BlockTiming { blocks: 1, min_us: us, max_us: us, mean_us: us })
            }
        }
    }

    /// Fused scaled update `y = alpha·A·x + beta·y` for any
    /// [`SpmvOperator`] — the iterative-solver iteration primitive
    /// ([`crate::solver`] calls this once or twice per iteration), saving
    /// both the temporary product vector and its zeroing pass.
    ///
    /// Partitioning is identical to [`SpmvEngine::run`]; each block runs
    /// [`run_range_axpby`](SpmvOperator::run_range_axpby) into its
    /// disjoint output segment. Results are **bit-identical** to the
    /// unfused compose (`tmp = A·x` into a zeroed buffer, then
    /// `y = alpha·tmp + beta·y` elementwise) by construction, for every
    /// format and partition count — property-tested in
    /// `tests/solver_convergence.rs`.
    ///
    /// With `alpha = 1.0, beta = 0.0` this is a plain overwrite-product
    /// (`y = A·x`, no pre-zeroing needed); with `beta = 1.0` it
    /// accumulates like [`SpmvEngine::run`] but scaled.
    ///
    /// ```
    /// use dtans::matrix::{Coo, Csr};
    /// use dtans::spmv::engine::SpmvEngine;
    /// let mut coo = Coo::new(2, 2);
    /// coo.push(0, 0, 2.0);
    /// coo.push(1, 1, 3.0);
    /// let m = Csr::from_coo(&coo);
    /// let engine = SpmvEngine::auto();
    /// let mut y = vec![10.0, 20.0];
    /// // y = -1·A·x + 1·y, i.e. a residual update r -= A·x.
    /// engine.run_axpby(&m, &[1.0, 1.0], -1.0, 1.0, &mut y).unwrap();
    /// assert_eq!(y, vec![8.0, 17.0]);
    /// // beta = 0 overwrites: y = A·x without zeroing y first.
    /// engine.run_axpby(&m, &[1.0, 1.0], 1.0, 0.0, &mut y).unwrap();
    /// assert_eq!(y, vec![2.0, 3.0]);
    /// ```
    pub fn run_axpby(
        &self,
        op: &dyn SpmvOperator,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y: &mut [f64],
    ) -> Result<()> {
        let (nrows, ncols) = op.dims();
        crate::spmv::check_dims(nrows, ncols, x, y)?;
        let prefix = op.cost_prefix();
        let (units, total) = prefix_stats(&prefix);
        let parts = self.parts_for(op.cost());
        match &self.pool {
            Some(pool) if parts > 1 && units > 1 => {
                let blocks = partition_prefix(&prefix, parts);
                run_blocks(
                    pool,
                    &blocks,
                    y,
                    |b| op.rows_through(b.end),
                    |b, seg| op.run_range_axpby_variant(b, x, alpha, beta, seg, self.variant),
                )
            }
            _ => op.run_range_axpby_variant(
                Block { start: 0, end: units, cost: total },
                x,
                alpha,
                beta,
                y,
                self.variant,
            ),
        }
    }

    /// Batched multi-RHS multiply (SpMM-style): `ys[.., j] = A·xs[.., j]`
    /// for every column of the contiguous column-major [`DenseMat`],
    /// fanning the (column × row-block) grid out over the pool — the
    /// serving shape where one matrix is multiplied against many vectors
    /// per batch. Returns a freshly zero-initialized output matrix. Each
    /// column is bit-identical to a serial single-vector multiply.
    ///
    /// ```
    /// use dtans::matrix::{Coo, Csr};
    /// use dtans::spmv::densemat::DenseMat;
    /// use dtans::spmv::engine::SpmvEngine;
    /// let mut coo = Coo::new(2, 2);
    /// coo.push(0, 1, 5.0);
    /// coo.push(1, 0, 7.0);
    /// let m = Csr::from_coo(&coo);
    /// let xs = DenseMat::from_cols(2, &[vec![1.0, 0.0], vec![0.0, 1.0]]).unwrap();
    /// let ys = SpmvEngine::auto().run_multi(&m, &xs).unwrap();
    /// assert_eq!(ys.into_cols(), vec![vec![0.0, 7.0], vec![5.0, 0.0]]);
    /// ```
    pub fn run_multi(&self, op: &dyn SpmvOperator, xs: &DenseMat) -> Result<DenseMat> {
        let (nrows, ncols) = op.dims();
        if xs.nrows() != ncols {
            return Err(DtansError::Dimension(format!(
                "matrix {nrows}x{ncols} with batch rhs rows {}",
                xs.nrows()
            )));
        }
        let k = xs.ncols();
        let mut ys = DenseMat::zeros(nrows, k);
        if nrows == 0 || k == 0 {
            return Ok(ys);
        }
        let prefix = op.cost_prefix();
        let (units, total) = prefix_stats(&prefix);
        match (&self.pool, self.batch_parts(op.cost(), k)) {
            (Some(pool), Some(parts)) => {
                let blocks = partition_prefix(&prefix, parts);
                if !blocks.is_empty() {
                    run_grid(pool, &blocks, op, xs, &mut ys, self.variant)?;
                }
            }
            _ => {
                let full = Block { start: 0, end: units, cost: total };
                op.run_range_multi_variant(full, xs, &mut ys.view_mut(), self.variant)?;
            }
        }
        Ok(ys)
    }

    /// Blocks per right-hand side a batched call of this shape will use
    /// (1 = serial) — lets the coordinator label a coalesced batch's
    /// kernel span without re-deriving the engine's decision.
    pub fn batch_blocks(&self, cost: usize, k: usize) -> usize {
        self.batch_parts(cost, k).unwrap_or(1)
    }

    /// Blocks *per right-hand side* for a batched call, or `None` for the
    /// serial path. The whole batch's cost decides whether to go parallel
    /// at all; the per-matrix block count then shrinks as the batch itself
    /// provides parallelism (with `k` right-hand sides and `n` threads,
    /// `ceil(n / k)` blocks already yield ≥ `n` independent jobs, so even
    /// one block per right-hand side is a real fan-out when `k > 1`).
    fn batch_parts(&self, cost: usize, k: usize) -> Option<usize> {
        if k == 0 {
            return None;
        }
        let parts = self.parts_for(cost.saturating_mul(k));
        match self.strategy {
            ParStrategy::Serial => None,
            // Auto below the cost threshold stays serial even for k > 1.
            ParStrategy::Auto if parts <= 1 => None,
            // Fixed(1) reaches here as Some(1), but its engine has no
            // pool, so every caller still takes the serial path.
            _ => Some(parts.div_ceil(k).max(1)),
        }
    }
}

/// `(units, total cost)` of a cost prefix — the two numbers `run` and
/// `run_multi` both derive before partitioning.
fn prefix_stats(prefix: &[usize]) -> (usize, usize) {
    match prefix.len() {
        0 | 1 => (0, 0),
        n => (n - 1, prefix[n - 1] - prefix[0]),
    }
}

/// Fan one output vector's blocks out over the pool. `row_end` maps a
/// block to its exclusive end *row* (blocks may be in units of slices);
/// `kernel` computes one block into its disjoint output segment.
/// Crate-visible so `spmv_csr_dtans_parallel` shares the same executor.
pub(crate) fn run_blocks(
    pool: &ThreadPool,
    blocks: &[Block],
    y: &mut [f64],
    row_end: impl Fn(&Block) -> usize,
    kernel: impl Fn(Block, &mut [f64]) -> Result<()> + Send + Sync,
) -> Result<()> {
    let mut slots: Vec<Result<()>> = Vec::new();
    slots.resize_with(blocks.len(), || Ok(()));
    let kernel = &kernel;
    {
        let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(blocks.len());
        let mut tail: &mut [f64] = y;
        let mut cursor = 0usize;
        for (b, slot) in blocks.iter().zip(slots.iter_mut()) {
            let b = *b;
            let r1 = row_end(&b);
            let (seg, rest) = tail.split_at_mut(r1 - cursor);
            tail = rest;
            cursor = r1;
            jobs.push(Box::new(move || *slot = kernel(b, seg)));
        }
        pool.scope_run(jobs);
    }
    slots.into_iter().find(|r| r.is_err()).unwrap_or(Ok(()))
}

/// [`run_blocks`] with each block's kernel bracketed by two clock reads
/// into a disjoint `times_us` slot (`times_us.len() == blocks.len()`).
/// Kept separate so the untimed executor — shared with
/// `spmv_csr_dtans_parallel` — stays exactly as it was.
fn run_blocks_timed(
    pool: &ThreadPool,
    blocks: &[Block],
    y: &mut [f64],
    times_us: &mut [u64],
    row_end: impl Fn(&Block) -> usize,
    kernel: impl Fn(Block, &mut [f64]) -> Result<()> + Send + Sync,
) -> Result<()> {
    let mut slots: Vec<Result<()>> = Vec::new();
    slots.resize_with(blocks.len(), || Ok(()));
    let kernel = &kernel;
    {
        let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(blocks.len());
        let mut tail: &mut [f64] = y;
        let mut cursor = 0usize;
        for ((b, slot), t) in blocks.iter().zip(slots.iter_mut()).zip(times_us.iter_mut()) {
            let b = *b;
            let r1 = row_end(&b);
            let (seg, rest) = tail.split_at_mut(r1 - cursor);
            tail = rest;
            cursor = r1;
            jobs.push(Box::new(move || {
                let t0 = std::time::Instant::now();
                *slot = kernel(b, seg);
                *t = t0.elapsed().as_micros() as u64;
            }));
        }
        pool.scope_run(jobs);
    }
    slots.into_iter().find(|r| r.is_err()).unwrap_or(Ok(()))
}

/// Fan the (column × block) grid of a batched multiply out over the pool;
/// every job writes a disjoint row segment of one output column (columns
/// are contiguous in the column-major [`DenseMat`], so segments come from
/// plain `split_at_mut`).
fn run_grid(
    pool: &ThreadPool,
    blocks: &[Block],
    op: &dyn SpmvOperator,
    xs: &DenseMat,
    ys: &mut DenseMat,
    variant: KernelVariant,
) -> Result<()> {
    let njobs = blocks.len() * xs.ncols();
    let mut slots: Vec<Result<()>> = Vec::new();
    slots.resize_with(njobs, || Ok(()));
    {
        let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(njobs);
        let mut slot_iter = slots.iter_mut();
        for (j, col) in ys.cols_mut().enumerate() {
            let x = xs.col(j);
            let mut tail: &mut [f64] = col;
            let mut cursor = 0usize;
            for b in blocks {
                let b = *b;
                let r1 = op.rows_through(b.end);
                let (seg, rest) = tail.split_at_mut(r1 - cursor);
                tail = rest;
                cursor = r1;
                let slot = slot_iter.next().expect("slot per job");
                jobs.push(Box::new(move || *slot = op.run_range_variant(b, x, seg, variant)));
            }
        }
        pool.scope_run(jobs);
    }
    slots.into_iter().find(|r| r.is_err()).unwrap_or(Ok(()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
    use crate::matrix::csr::Csr;
    use crate::matrix::gen::structured::{banded, powerlaw_rows};
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::matrix::Sell;
    use crate::spmv::operator::DtansOperator;
    use crate::util::rng::Xoshiro256;

    fn test_matrix(seed: u64) -> Csr {
        let mut rng = Xoshiro256::seeded(seed);
        let mut m = powerlaw_rows(300, 6.0, 1.1, &mut rng);
        assign_values(&mut m, ValueDist::FewDistinct(7), &mut rng);
        m
    }

    #[test]
    fn csr_parallel_matches_serial_bitwise() {
        let m = test_matrix(1);
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut want = vec![0.25; m.nrows];
        crate::spmv::csr::spmv_csr(&m, &x, &mut want).unwrap();
        for strategy in [ParStrategy::Serial, ParStrategy::Fixed(3), ParStrategy::Fixed(16)] {
            let engine = SpmvEngine::new(strategy);
            let mut got = vec![0.25; m.nrows];
            engine.run(&m, &x, &mut got).unwrap();
            assert_eq!(got, want, "strategy {strategy:?}");
        }
    }

    #[test]
    fn dtans_parallel_matches_serial_bitwise() {
        let m = test_matrix(2);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.07).cos()).collect();
        let mut want = vec![0.0; m.nrows];
        crate::spmv::csr_dtans::spmv_csr_dtans(&enc, &x, &mut want).unwrap();
        let op = DtansOperator::new(enc);
        let engine = SpmvEngine::new(ParStrategy::Fixed(5));
        let mut got = vec![0.0; m.nrows];
        engine.run(&op, &x, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn sell_parallel_matches_serial_bitwise() {
        let m = test_matrix(3);
        let sell = Sell::from_csr(&m, 32);
        let x: Vec<f64> = (0..m.ncols).map(|i| i as f64 * 0.01 - 1.0).collect();
        let mut want = vec![0.0; m.nrows];
        crate::spmv::sell::spmv_sell(&sell, &x, &mut want).unwrap();
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let mut got = vec![0.0; m.nrows];
        engine.run(&sell, &x, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn unrolled_variants_bit_identical_across_strategies_and_close_to_scalar() {
        // The KernelVariant contract: for a fixed variant, every strategy
        // and partition count gives the exact bits of that variant's
        // serial run; across variants only tight closeness holds.
        let m = test_matrix(12);
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.2).cos()).collect();
        let mut scalar = vec![0.0; m.nrows];
        SpmvEngine::serial().run(&m, &x, &mut scalar).unwrap();
        for variant in [KernelVariant::Unrolled4, KernelVariant::Unrolled8] {
            let mut serial = vec![0.0; m.nrows];
            SpmvEngine::serial().with_kernel_variant(variant).run(&m, &x, &mut serial).unwrap();
            for strategy in [ParStrategy::Fixed(3), ParStrategy::Fixed(16)] {
                let engine = SpmvEngine::new(strategy).with_kernel_variant(variant);
                assert_eq!(engine.kernel_variant(), variant);
                let mut got = vec![0.0; m.nrows];
                engine.run(&m, &x, &mut got).unwrap();
                assert_eq!(got, serial, "{variant:?} {strategy:?}");
            }
            for (a, b) in serial.iter().zip(&scalar) {
                let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
                assert!(rel <= 1e-9, "{variant:?}: {a} vs scalar {b}");
            }
        }
    }

    #[test]
    fn run_axpby_matches_unfused_compose_across_strategies() {
        // CSR exercises the fused override, dtANS the default temp-based
        // path; both must equal the unfused compose for every strategy.
        let m = test_matrix(8);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let dtans = DtansOperator::new(enc);
        let mut rng = Xoshiro256::seeded(9);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let y0: Vec<f64> = (0..m.nrows).map(|_| rng.next_f64() * 2.0).collect();
        let ops: [&dyn SpmvOperator; 2] = [&m, &dtans];
        for op in ops {
            for &(alpha, beta) in &[(1.0, 0.0), (-1.0, 1.0), (0.5, -2.0)] {
                let mut tmp = vec![0.0; m.nrows];
                SpmvEngine::serial().run(op, &x, &mut tmp).unwrap();
                let want: Vec<f64> =
                    y0.iter().zip(&tmp).map(|(y, t)| alpha * t + beta * y).collect();
                for strategy in
                    [ParStrategy::Serial, ParStrategy::Fixed(4), ParStrategy::Fixed(13)]
                {
                    let mut got = y0.clone();
                    SpmvEngine::new(strategy).run_axpby(op, &x, alpha, beta, &mut got).unwrap();
                    assert_eq!(got, want, "{} {strategy:?} a={alpha} b={beta}", op.format_tag());
                }
            }
        }
    }

    #[test]
    fn run_multi_matches_repeated_run() {
        let m = test_matrix(4);
        let mut rng = Xoshiro256::seeded(5);
        let cols: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect())
            .collect();
        let xs = DenseMat::from_cols(m.ncols, &cols).unwrap();
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let ys = engine.run_multi(&m, &xs).unwrap();
        for (x, y) in cols.iter().zip(ys.into_cols()) {
            let mut want = vec![0.0; m.nrows];
            crate::spmv::csr::spmv_csr(&m, x, &mut want).unwrap();
            assert_eq!(y, want);
        }
    }

    #[test]
    fn batch_dim_mismatch_is_error() {
        let m = test_matrix(6);
        let engine = SpmvEngine::serial();
        let xs = DenseMat::zeros(m.ncols + 1, 2);
        assert!(engine.run_multi(&m, &xs).is_err());
    }

    #[test]
    fn dim_mismatch_is_error_on_parallel_path() {
        let m = test_matrix(7);
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let x = vec![0.0; m.ncols + 1];
        let mut y = vec![0.0; m.nrows];
        assert!(engine.run(&m, &x, &mut y).is_err());
    }

    #[test]
    fn empty_matrix_is_fine() {
        let m = Csr::new(0, 0);
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let mut y = Vec::new();
        engine.run(&m, &[], &mut y).unwrap();
        let ys = engine.run_multi(&m, &DenseMat::zeros(0, 0)).unwrap();
        assert!(ys.into_cols().is_empty());
        // k > 0 over an empty matrix: k empty output columns, no panic.
        let ys = engine.run_multi(&m, &DenseMat::zeros(0, 3)).unwrap();
        assert_eq!(ys.into_cols(), vec![Vec::<f64>::new(); 3]);
    }

    #[test]
    fn run_timed_is_bit_identical_and_reports_block_spread() {
        let m = test_matrix(11);
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut want = vec![0.0; m.nrows];
        crate::spmv::csr::spmv_csr(&m, &x, &mut want).unwrap();
        for strategy in [ParStrategy::Serial, ParStrategy::Fixed(4)] {
            let engine = SpmvEngine::new(strategy);
            let mut got = vec![0.0; m.nrows];
            let t = engine.run_timed(&m, &x, &mut got).unwrap();
            assert_eq!(got, want, "strategy {strategy:?}");
            let expect_blocks = if engine.is_parallel() { 4 } else { 1 };
            assert_eq!(t.blocks, expect_blocks, "strategy {strategy:?}");
            assert!(t.min_us <= t.mean_us && t.mean_us <= t.max_us);
        }
        // The dimension check still fires on the timed path.
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let bad_x = vec![0.0; m.ncols + 1];
        let mut y = vec![0.0; m.nrows];
        assert!(engine.run_timed(&m, &bad_x, &mut y).is_err());
    }

    #[test]
    fn batch_blocks_matches_will_batch_parallel() {
        let engine = SpmvEngine::new(ParStrategy::Fixed(8));
        assert!(engine.will_batch_parallel(1 << 20, 4));
        assert_eq!(engine.batch_blocks(1 << 20, 4), 2); // ceil(8/4)
        let serial = SpmvEngine::serial();
        assert!(!serial.will_batch_parallel(1 << 20, 4));
        assert_eq!(serial.batch_blocks(1 << 20, 4), 1);
    }

    #[test]
    fn auto_runs_small_inputs_serially_and_large_in_parallel() {
        // Behavioral check: both paths must give the same (bit-identical)
        // answer regardless of which side of MIN_PAR_COST the input lands.
        let engine = SpmvEngine::auto();
        for n in [100usize, 20_000] {
            let mut m = banded(n, 2);
            assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(8));
            let x = vec![1.0; m.ncols];
            let mut want = vec![0.0; m.nrows];
            crate::spmv::csr::spmv_csr(&m, &x, &mut want).unwrap();
            let mut got = vec![0.0; m.nrows];
            engine.run(&m, &x, &mut got).unwrap();
            assert_eq!(got, want);
        }
    }
}
