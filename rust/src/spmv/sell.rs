//! SELL SpMVM kernel: column-major within a slice, one lane per row — the
//! fully coalesced schedule SELL was designed for [20].

use crate::matrix::sell::Sell;
use crate::util::error::Result;

/// `y += A·x` over a SELL matrix (padding contributes 0).
///
/// ```
/// use dtans::matrix::{Coo, Csr, Sell};
/// use dtans::spmv::{spmv_csr, spmv_sell};
/// let mut coo = Coo::new(3, 3);
/// for &(r, c, v) in &[(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0)] {
///     coo.push(r, c, v);
/// }
/// let m = Csr::from_coo(&coo);
/// let sell = Sell::from_csr(&m, 2);
/// let x = [1.0, 1.0, 1.0];
/// let (mut y, mut want) = (vec![0.0; 3], vec![0.0; 3]);
/// spmv_sell(&sell, &x, &mut y).unwrap();
/// spmv_csr(&m, &x, &mut want).unwrap();
/// assert_eq!(y, want);
/// ```
pub fn spmv_sell(m: &Sell, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    spmv_sell_slice_range(m, 0, m.nslices(), x, y)
}

/// SELL kernel over slices `s0..s1`; `y_seg` spans rows
/// `s0 * slice_height .. min(s1 * slice_height, nrows)`. The whole-matrix
/// [`spmv_sell`] is the `0..nslices` case and the parallel engine fans out
/// disjoint ranges, so both paths share one loop and bit-identical results
/// hold by construction.
pub(crate) fn spmv_sell_slice_range(
    m: &Sell,
    s0: usize,
    s1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    let h = m.slice_height;
    let row0 = s0 * h;
    for s in s0..s1 {
        let r_base = s * h;
        let width = m.slice_widths[s] as usize;
        let base = m.slice_ptr[s];
        for j in 0..width {
            let col_base = base + j * h;
            for rr in 0..h {
                let r = r_base + rr;
                if r < m.nrows {
                    let idx = col_base + rr;
                    // Padded cells have value 0.0: the FMA is a no-op, as on
                    // the GPU (no branch).
                    y_seg[r - row0] += m.vals[idx] * x[m.cols[idx] as usize];
                }
            }
        }
    }
    Ok(())
}

/// Fused scaled update over slices `s0..s1`:
/// `y_seg[i] = alpha·(A·x)[row] + beta·y_seg[i]`.
///
/// [`spmv_sell_slice_range`] walks a slice column-major and accumulates
/// each row's terms directly into `y_seg` in ascending-`j` order from a
/// `0.0` start; this variant walks row-major with a local accumulator,
/// which performs the *same additions in the same order per row* (padded
/// cells still contribute `0.0`), then applies `alpha·acc + beta·y` — the
/// exact operations of the unfused "multiply into a zeroed temporary, then
/// axpby" compose, minus the temporary.
pub(crate) fn spmv_sell_slice_range_axpby(
    m: &Sell,
    s0: usize,
    s1: usize,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    let h = m.slice_height;
    let row0 = s0 * h;
    for s in s0..s1 {
        let r_base = s * h;
        let width = m.slice_widths[s] as usize;
        let base = m.slice_ptr[s];
        for rr in 0..h {
            let r = r_base + rr;
            if r >= m.nrows {
                break; // tail slice: rows past nrows do not exist
            }
            let mut acc = 0.0;
            for j in 0..width {
                let idx = base + j * h + rr;
                acc += m.vals[idx] * x[m.cols[idx] as usize];
            }
            y_seg[r - row0] = alpha * acc + beta * y_seg[r - row0];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::sell::Sell;
    use crate::spmv::csr::spmv_csr;
    use crate::util::propcheck::assert_close;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn slice_range_blocks_reassemble_bitwise() {
        let mut rng = Xoshiro256::seeded(5);
        let m = crate::matrix::gen::structured::powerlaw_rows(90, 5.0, 1.1, &mut rng);
        let sell = Sell::from_csr(&m, 8);
        let x: Vec<f64> = (0..90).map(|_| rng.next_f64()).collect();
        let mut want = vec![0.0; 90];
        spmv_sell(&sell, &x, &mut want).unwrap();
        let mut got = vec![0.0; 90];
        let nsl = sell.nslices();
        for (s0, s1) in [(0usize, 3usize), (3, 7), (7, nsl)] {
            let r0 = s0 * 8;
            let r1 = (s1 * 8).min(90);
            spmv_sell_slice_range(&sell, s0, s1, &x, &mut got[r0..r1]).unwrap();
        }
        assert_eq!(got, want); // bit-identical, not just close
    }

    #[test]
    fn axpby_slice_range_matches_unfused_compose_bitwise() {
        let mut rng = Xoshiro256::seeded(6);
        let m = crate::matrix::gen::structured::powerlaw_rows(70, 4.0, 1.2, &mut rng);
        let sell = Sell::from_csr(&m, 8);
        let x: Vec<f64> = (0..70).map(|_| rng.next_f64() - 0.5).collect();
        let y0: Vec<f64> = (0..70).map(|_| rng.next_f64() * 3.0).collect();
        for &(alpha, beta) in &[(1.0, 0.0), (-0.5, 1.0), (2.5, -0.75)] {
            let mut tmp = vec![0.0; 70];
            spmv_sell(&sell, &x, &mut tmp).unwrap();
            let want: Vec<f64> =
                y0.iter().zip(&tmp).map(|(y, t)| alpha * t + beta * y).collect();
            let mut got = y0.clone();
            spmv_sell_slice_range_axpby(&sell, 0, sell.nslices(), &x, alpha, beta, &mut got)
                .unwrap();
            assert_eq!(got, want, "alpha={alpha} beta={beta}");
        }
    }

    #[test]
    fn matches_csr_various_slice_heights() {
        let mut rng = Xoshiro256::seeded(4);
        let m = crate::matrix::gen::structured::powerlaw_rows(150, 5.0, 1.0, &mut rng);
        let x: Vec<f64> = (0..150).map(|_| rng.next_f64()).collect();
        let mut want = vec![0.0; 150];
        spmv_csr(&m, &x, &mut want).unwrap();
        for h in [1usize, 2, 7, 32, 64] {
            let sell = Sell::from_csr(&m, h);
            let mut y = vec![0.0; 150];
            spmv_sell(&sell, &x, &mut y).unwrap();
            assert_close(&y, &want, 1e-12, 1e-15).unwrap();
        }
    }
}
