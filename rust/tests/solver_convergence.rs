//! Solver acceptance tests: CG convergence to 1e-10 relative residual on
//! a known SPD system across **all five formats**, with bit-identical
//! iterate histories across `ParStrategy::{Serial, Fixed(n)}` for every
//! partition count 1..=16; a property test that the fused `run_axpby`
//! engine path matches the unfused `run` + axpby compose bitwise; and the
//! service-level single-pin-per-solve guarantee asserted via store
//! counters.

use dtans::coordinator::service::{ServiceConfig, SpmvService};
use dtans::format::csr_dtans::EncodeOptions;
use dtans::matrix::csr::Csr;
use dtans::matrix::gen::structured::stencil2d5;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::solver::{bicgstab_with, cg_with, SolveMethod, SolverConfig};
use dtans::spmv::engine::{ParStrategy, SpmvEngine};
use dtans::spmv::operator::FormatRegistry;
use dtans::spmv::spmv_csr;
use dtans::util::rng::Xoshiro256;
use std::sync::atomic::Ordering;

/// The known SPD system: a 2D Poisson matrix small enough that even the
/// dense-oracle operator builds (576 rows, ~2.8k nnz).
fn spd() -> Csr {
    stencil2d5(24, 24)
}

fn rhs(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i as f64) * 0.37).sin() + 0.5).collect()
}

#[test]
fn cg_hits_1e10_bitwise_across_all_partition_counts_for_every_format() {
    let m = spd();
    let b = rhs(m.nrows);
    let cfg = SolverConfig { tol: 1e-10, max_iters: 2000, par: ParStrategy::Serial };
    for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
        let op = op.expect(tag);
        let serial = cg_with(&SpmvEngine::serial(), op.as_ref(), &b, None, &cfg).unwrap();
        assert!(serial.report.converged(), "{tag}: {:?}", serial.report.termination);
        assert!(serial.report.final_residual() <= 1e-10, "{tag}");
        assert!(serial.report.iterations > 10, "{tag}: trivial solve proves nothing");
        // The solution truly solves the system (checked against the
        // serial CSR ground truth, independent of the solved format).
        let mut ax = vec![0.0; m.nrows];
        spmv_csr(&m, &serial.x, &mut ax).unwrap();
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-7, "{tag}: Ax={l} vs b={r}");
        }
        // Every partition count 1..=16 reproduces the iterate history
        // bit for bit: same iteration count, same residual at every
        // step, same final x.
        for parts in 1..=16usize {
            let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
            let sol = cg_with(&engine, op.as_ref(), &b, None, &cfg).unwrap();
            assert_eq!(
                sol.report.iterations, serial.report.iterations,
                "{tag} parts={parts}"
            );
            assert_eq!(
                sol.report.residuals, serial.report.residuals,
                "{tag} parts={parts}: residual history diverged"
            );
            assert_eq!(sol.x, serial.x, "{tag} parts={parts}: iterate diverged");
        }
    }
}

#[test]
fn formats_agree_on_the_cg_solution() {
    // Cross-format: every format converges to the same solution within
    // tight tolerance. (Bitwise identity holds *within* a format across
    // strategies — see above — not *across* formats: the dtANS lockstep
    // decoder reassociates its per-row accumulation.)
    let m = spd();
    let b = rhs(m.nrows);
    let cfg = SolverConfig { tol: 1e-10, max_iters: 2000, par: ParStrategy::Serial };
    let engine = SpmvEngine::serial();
    let mut reference: Option<Vec<f64>> = None;
    for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
        let op = op.expect(tag);
        let sol = cg_with(&engine, op.as_ref(), &b, None, &cfg).unwrap();
        match &reference {
            None => reference = Some(sol.x),
            Some(want) => {
                for (l, r) in sol.x.iter().zip(want) {
                    assert!((l - r).abs() < 1e-8, "{tag}: {l} vs {r}");
                }
            }
        }
    }
}

#[test]
fn fused_axpby_matches_unfused_compose_bitwise() {
    // Property test over random matrices, formats, partition counts and
    // (alpha, beta) pairs: run_axpby == run-into-zeroed-tmp then axpby.
    let mut rng = Xoshiro256::seeded(41);
    for seed in 0..4u64 {
        let mut m =
            dtans::matrix::gen::structured::powerlaw_rows(200, 5.0, 1.1, &mut rng);
        assign_values(&mut m, ValueDist::FewDistinct(9), &mut Xoshiro256::seeded(seed));
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let y0: Vec<f64> = (0..m.nrows).map(|_| rng.next_f64() * 4.0 - 2.0).collect();
        let alpha = rng.next_f64() * 4.0 - 2.0;
        let beta = rng.next_f64() * 4.0 - 2.0;
        let cases =
            [(1.0, 0.0), (alpha, beta), (-1.0, 1.0), (0.0, 1.0), (alpha, 0.0), (0.0, 0.0)];
        for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
            let op = op.expect(tag);
            for &(a, bta) in &cases {
                // Unfused reference on the serial engine.
                let mut tmp = vec![0.0; m.nrows];
                SpmvEngine::serial().run(op.as_ref(), &x, &mut tmp).unwrap();
                let want: Vec<f64> =
                    y0.iter().zip(&tmp).map(|(y, t)| a * t + bta * y).collect();
                for parts in [1usize, 2, 5, 16] {
                    let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
                    let mut got = y0.clone();
                    engine.run_axpby(op.as_ref(), &x, a, bta, &mut got).unwrap();
                    assert_eq!(
                        got, want,
                        "{tag} seed={seed} parts={parts} alpha={a} beta={bta}"
                    );
                }
            }
        }
    }
}

#[test]
fn bicgstab_histories_are_bitwise_stable_across_partitions_too() {
    let m = spd();
    let b = rhs(m.nrows);
    let cfg = SolverConfig { tol: 1e-10, max_iters: 2000, par: ParStrategy::Serial };
    let serial = bicgstab_with(&SpmvEngine::serial(), &m, &b, None, &cfg).unwrap();
    assert!(serial.report.converged());
    for parts in [2usize, 7, 16] {
        let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
        let sol = bicgstab_with(&engine, &m, &b, None, &cfg).unwrap();
        assert_eq!(sol.report.residuals, serial.report.residuals, "parts={parts}");
        assert_eq!(sol.x, serial.x, "parts={parts}");
    }
}

#[test]
fn service_solve_pins_once_for_the_whole_solve() {
    let svc = SpmvService::start(ServiceConfig::default());
    let m = spd();
    let id = svc.register("poisson", m.clone()).unwrap();
    let b = rhs(m.nrows);
    let cfg = SolverConfig { tol: 1e-10, max_iters: 2000, ..Default::default() };

    let acquires0 = svc.metrics.acquires.load(Ordering::Relaxed);
    let sol = svc.solve(id, SolveMethod::Cg, &b, &cfg).unwrap();
    assert!(sol.report.converged());
    assert!(sol.report.iterations > 10);
    // The acceptance bar: an N-iteration solve is exactly ONE store
    // acquire (one pin held throughout), and the pin is released after.
    assert_eq!(
        svc.metrics.acquires.load(Ordering::Relaxed) - acquires0,
        1,
        "a solve must not re-acquire per iteration"
    );
    assert_eq!(svc.store().pin_count(id), 0, "the solve's pin must be released");

    // Solver metrics: one solve, one converged, iteration quantiles over
    // that single sample, and ONE request-level latency sample.
    let s = svc.metrics.solver_summary();
    assert_eq!((s.solves, s.converged, s.diverged), (1, 1, 0));
    assert_eq!(s.iters_count, 1);
    assert_eq!(s.iters_p50, sol.report.iterations as u64);
    let fs = svc.metrics.format_summary("csr").unwrap();
    assert_eq!(
        (fs.completed, fs.latency.count),
        (1, 1),
        "a solve's N iterations must land as one latency sample, not N"
    );
    assert!(svc.metrics.report().contains("solver: solves=1 converged=1"));

    // A second solve with BiCGStab agrees with CG's answer.
    let sol2 = svc.solve(id, SolveMethod::BiCgStab, &b, &cfg).unwrap();
    assert!(sol2.report.converged());
    for (l, r) in sol2.x.iter().zip(&sol.x) {
        assert!((l - r).abs() < 1e-7);
    }
    assert_eq!(svc.metrics.solver_summary().solves, 2);
}
