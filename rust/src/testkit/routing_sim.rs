//! Deterministic routing simulator: the stability proof for the
//! [`AdaptiveRouter`](crate::coordinator::AdaptiveRouter).
//!
//! Adaptive routing is a feedback loop (decide → execute → observe →
//! maybe flip), and feedback loops have failure modes that unit tests
//! on single methods cannot exhibit: failure to converge onto the best
//! arm, route *flapping* under noisy latencies, exploration samples
//! leaking into the conservation counters. This module closes that gap
//! without ever running a kernel:
//!
//! * **Injected clock.** There are no sleeps and no wall-clock reads.
//!   Time is the router's own observation counter — one simulated
//!   request per step, and [`RouteFlip::at_observation`] is the clock
//!   stamp every convergence assertion reads.
//! * **Seeded latency oracle.** [`LatencyOracle`] synthesizes per-arm
//!   latencies from an [`ArmProfile`] (base cost, per-step drift,
//!   uniform jitter, periodic spikes) using one seeded
//!   [`Xoshiro256`] stream *per arm*, so an arm's k-th sample is
//!   identical no matter how draws interleave across arms. An optional
//!   mid-run **reversal** swaps the arms' base costs at a chosen step
//!   (the regime the incumbent was learned under stops being true).
//! * **The real router.** [`run_routing_sim`] drives an actual
//!   [`AdaptiveRouter`] — same EWMA, same hysteresis, same counters the
//!   service uses — through the synthetic trace and returns a
//!   [`SimOutcome`]: the full decision trace, the flip trace, the
//!   conservation counters, the convergence step, and the
//!   post-convergence p50 next to the best static arm's p50.
//!
//! Three canned regimes ([`Regime`]) cover the interesting dynamics:
//! `Stationary` (a dtANS-hostile matrix where the static choice is
//! simply wrong), `Drifting` (the incumbent degrades linearly until it
//! loses), and `BimodalNoisy` (heavy jitter plus periodic latency
//! spikes on both arms — the flap-inducing case hysteresis exists
//! for). Everything is seeded: the same [`SimConfig`] always produces
//! the same [`SimOutcome`], bit for bit, so assertions like "exactly
//! one flip, at observation ≤ 200" are stable in CI.

use crate::coordinator::adaptive::{
    AdaptiveConfig, AdaptiveRouter, Arm, RouteCounters, RouteFlip, SeedSource,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::FormatChoice;
use crate::obs::ObsConfig;
use crate::spmv::engine::KernelVariant;
use crate::util::rng::Xoshiro256;
use std::sync::Arc;

/// The single simulated matrix id.
const SIM_MATRIX: u64 = 1;

/// Latency-generating profile for one arm.
#[derive(Debug, Clone, Copy)]
pub struct ArmProfile {
    /// The arm this profile describes.
    pub arm: Arm,
    /// Baseline latency (µs).
    pub base_us: f64,
    /// Linear drift: added as `drift_us_per_step · step` (models an
    /// incumbent that degrades as the workload shifts).
    pub drift_us_per_step: f64,
    /// Uniform jitter half-width: each sample adds `U[-j, j)` µs.
    pub jitter_us: f64,
    /// Every Nth sample of this arm spikes (`0` = never) — the bimodal
    /// tail (an eviction, a page fault, a neighbor burst).
    pub spike_every: u64,
    /// Spike magnitude (µs), added on spiking samples.
    pub spike_us: f64,
}

impl ArmProfile {
    /// A flat profile: constant base cost with a little jitter.
    pub fn flat(arm: Arm, base_us: f64, jitter_us: f64) -> ArmProfile {
        ArmProfile { arm, base_us, drift_us_per_step: 0.0, jitter_us, spike_every: 0, spike_us: 0.0 }
    }
}

/// Canned latency regimes (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// dtANS-hostile: the static choice (dtANS) is 1.6× slower than the
    /// CSR baseline and stays that way. The router must converge to CSR.
    Stationary,
    /// The incumbent starts fastest but degrades linearly until the flat
    /// challenger wins. Exactly the case static routing can never fix.
    Drifting,
    /// Heavy jitter plus periodic spikes on both arms, with a 2× true
    /// gap underneath. Hysteresis must find the gap without flapping.
    BimodalNoisy,
}

/// One simulator run: the arm profiles, the routing config under test,
/// and the trace shape.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Router configuration under test.
    pub adaptive: AdaptiveConfig,
    /// Latency profile per arm (the arm list defines the admissible
    /// set; arms should be [`Arm::format`]-shaped unless
    /// `adaptive.variant_arms` / `serial_arms` expand the space).
    pub profiles: Vec<ArmProfile>,
    /// The static `RoutePolicy` choice — the incumbent at step 0.
    pub static_choice: FormatChoice,
    /// Simulated request count (one decide/observe pair per step).
    pub steps: u64,
    /// Swap the arms' base costs from this step on (`None` = never):
    /// the learned regime reverses mid-run and the router must follow.
    pub reversal_at: Option<u64>,
    /// Seed for the latency oracle's per-arm streams (independent of
    /// the router's exploration seed).
    pub oracle_seed: u64,
}

impl SimConfig {
    /// Build the canned [`Regime`] scenarios. In every regime the
    /// static choice is dtANS and the router explores 20% of traffic;
    /// hysteresis stays at the production defaults (10% margin, K=3,
    /// 2 observations minimum).
    pub fn regime(regime: Regime) -> SimConfig {
        let adaptive = AdaptiveConfig { explore_fraction: 0.2, ..AdaptiveConfig::enabled() };
        let dtans = Arm::format(FormatChoice::CsrDtans);
        let csr = Arm::format(FormatChoice::Csr);
        let (profiles, steps) = match regime {
            Regime::Stationary => (
                vec![ArmProfile::flat(dtans, 400.0, 20.0), ArmProfile::flat(csr, 250.0, 20.0)],
                400,
            ),
            Regime::Drifting => (
                vec![
                    ArmProfile {
                        arm: dtans,
                        base_us: 240.0,
                        drift_us_per_step: 1.2,
                        jitter_us: 15.0,
                        spike_every: 0,
                        spike_us: 0.0,
                    },
                    ArmProfile::flat(csr, 400.0, 15.0),
                ],
                400,
            ),
            Regime::BimodalNoisy => (
                vec![
                    ArmProfile {
                        arm: dtans,
                        base_us: 500.0,
                        drift_us_per_step: 0.0,
                        jitter_us: 25.0,
                        spike_every: 9,
                        spike_us: 350.0,
                    },
                    ArmProfile {
                        arm: csr,
                        base_us: 250.0,
                        drift_us_per_step: 0.0,
                        jitter_us: 25.0,
                        spike_every: 7,
                        spike_us: 350.0,
                    },
                ],
                500,
            ),
        };
        SimConfig {
            adaptive,
            profiles,
            static_choice: FormatChoice::CsrDtans,
            steps,
            reversal_at: None,
            oracle_seed: 0x0051_D0_0051_D0,
        }
    }

    /// The same regime with a base-cost reversal at `step`.
    pub fn with_reversal(mut self, step: u64) -> SimConfig {
        self.reversal_at = Some(step);
        self
    }
}

struct OracleArm {
    profile: ArmProfile,
    rng: Xoshiro256,
    samples: u64,
}

/// Seeded per-arm latency synthesizer (see the module docs). One RNG
/// stream per arm: an arm's k-th sample never depends on what the
/// other arms were asked, which is what makes the best-static-arm
/// replay comparable to the live run.
pub struct LatencyOracle {
    arms: Vec<OracleArm>,
    reversal_at: Option<u64>,
}

impl LatencyOracle {
    /// Build an oracle over `profiles`, with per-arm streams derived
    /// from `seed` and an optional base-cost reversal step.
    pub fn new(profiles: &[ArmProfile], seed: u64, reversal_at: Option<u64>) -> LatencyOracle {
        let arms = profiles
            .iter()
            .enumerate()
            .map(|(i, p)| OracleArm {
                profile: *p,
                rng: Xoshiro256::seeded(
                    seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                ),
                samples: 0,
            })
            .collect();
        LatencyOracle { arms, reversal_at }
    }

    /// Synthesize the latency of one request on `arm` at trace `step`.
    /// After the reversal step the arms trade base costs (profile `i`
    /// uses profile `len-1-i`'s base); drift, jitter and spikes stay
    /// with the arm.
    pub fn sample(&mut self, arm: Arm, step: u64) -> f64 {
        let reversed = self.reversal_at.is_some_and(|r| step >= r);
        let n = self.arms.len();
        let idx = self
            .arms
            .iter()
            .position(|a| a.profile.arm == arm)
            .expect("sampled arm has a profile");
        let base = if reversed {
            self.arms[n - 1 - idx].profile.base_us
        } else {
            self.arms[idx].profile.base_us
        };
        let a = &mut self.arms[idx];
        let p = a.profile;
        let mut lat = base + p.drift_us_per_step * step as f64;
        lat += (a.rng.next_f64() * 2.0 - 1.0) * p.jitter_us;
        a.samples += 1;
        if p.spike_every > 0 && a.samples % p.spike_every == 0 {
            lat += p.spike_us;
        }
        lat.max(1.0)
    }
}

/// Everything a stability assertion needs from one simulator run.
/// Fully deterministic given the [`SimConfig`] (derives `PartialEq` so
/// tests can assert two runs are identical, decision for decision).
#[derive(Debug, Clone, PartialEq)]
pub struct SimOutcome {
    /// The arm served at each step, in order (the decision trace).
    pub decisions: Vec<Arm>,
    /// The committed flip trace ([`RouteFlip::at_observation`] is the
    /// injected clock).
    pub flips: Vec<RouteFlip>,
    /// Conservation counters: `explored + exploited == routed` and
    /// `routed == steps` must hold.
    pub counters: RouteCounters,
    /// Incumbent after the last step.
    pub final_incumbent: Arm,
    /// The truly-best arm of the *final* regime (lowest replayed p50
    /// over the post-reversal window).
    pub best_arm: Arm,
    /// Observation-clock stamp after which the incumbent equals
    /// [`SimOutcome::best_arm`] and never changes again (`Some(0)` when
    /// the static choice was already best; `None` when the run never
    /// converged).
    pub converged_at: Option<u64>,
    /// p50 of the latencies actually served after convergence
    /// (exploration samples included — ε-greedy pays for its samples).
    pub post_convergence_p50_us: f64,
    /// p50 an oracle-replayed best static arm would have served over
    /// the same window.
    pub best_static_p50_us: f64,
}

fn p50(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

/// Run one simulated trace through a real [`AdaptiveRouter`]: register
/// the matrix (arm list = the profiles' formats, [`SeedSource::Static`]
/// — the cost model starts blind, exactly like a service without a CSR
/// original to seed from), then `decide → oracle → observe` once per
/// step. No threads, no sleeps, no kernels.
pub fn run_routing_sim(cfg: &SimConfig) -> SimOutcome {
    let metrics = Arc::new(Metrics::with_obs(ObsConfig::default()));
    let router = AdaptiveRouter::new(cfg.adaptive, metrics);
    let mut admissible: Vec<FormatChoice> = Vec::new();
    for p in &cfg.profiles {
        if !admissible.contains(&p.arm.choice) {
            admissible.push(p.arm.choice);
        }
    }
    router.register_matrix(
        SIM_MATRIX,
        cfg.static_choice,
        &admissible,
        KernelVariant::default(),
        &[],
        SeedSource::Static,
    );

    let mut oracle = LatencyOracle::new(&cfg.profiles, cfg.oracle_seed, cfg.reversal_at);
    let mut decisions = Vec::with_capacity(cfg.steps as usize);
    let mut served = Vec::with_capacity(cfg.steps as usize);
    for step in 0..cfg.steps {
        let d = router.decide(SIM_MATRIX).expect("simulated matrix is registered");
        let lat = oracle.sample(d.arm, step);
        router.observe(SIM_MATRIX, d.arm, lat);
        decisions.push(d.arm);
        served.push(lat);
    }

    let flips = router.flips();
    let counters = router.counters();
    let final_incumbent = router.incumbent(SIM_MATRIX).expect("still registered");

    // Best arm of the *final* regime: replay each arm alone on a fresh
    // oracle over the post-reversal window and take the lowest p50.
    let eval_start = cfg.reversal_at.unwrap_or(0);
    let mut best_arm = cfg.profiles[0].arm;
    let mut best_static_p50_us = f64::INFINITY;
    for p in &cfg.profiles {
        let mut o = LatencyOracle::new(&cfg.profiles, cfg.oracle_seed, cfg.reversal_at);
        let lats: Vec<f64> = (eval_start..cfg.steps).map(|s| o.sample(p.arm, s)).collect();
        let q = p50(&lats);
        if q < best_static_p50_us {
            best_static_p50_us = q;
            best_arm = p.arm;
        }
    }

    let converged_at = if final_incumbent != best_arm {
        None
    } else {
        match flips.last() {
            None => Some(0),
            Some(f) => Some(f.at_observation),
        }
    };

    // Post-convergence window: from the later of convergence and the
    // reversal (one observation ≈ one step on this single-matrix trace).
    let start = converged_at.unwrap_or(eval_start).max(eval_start) as usize;
    let tail = if start < served.len() { &served[start..] } else { &served[..] };
    let post_convergence_p50_us = p50(tail);

    SimOutcome {
        decisions,
        flips,
        counters,
        final_incumbent,
        best_arm,
        converged_at,
        post_convergence_p50_us,
        best_static_p50_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dtans() -> Arm {
        Arm::format(FormatChoice::CsrDtans)
    }

    fn csr() -> Arm {
        Arm::format(FormatChoice::Csr)
    }

    #[test]
    fn stationary_hostile_regime_converges_to_the_best_arm() {
        let out = run_routing_sim(&SimConfig::regime(Regime::Stationary));
        assert_eq!(out.best_arm, csr());
        assert_eq!(out.final_incumbent, csr(), "router must leave the hostile static choice");
        assert_eq!(out.flips.len(), 1, "one committed flip, no flapping: {:?}", out.flips);
        assert_eq!((out.flips[0].from, out.flips[0].to), (dtans(), csr()));
        // ε = 0.2 with K = 3 and min_observations = 2: convergence is a
        // handful of exploration samples, far inside half the trace.
        let at = out.converged_at.expect("converged");
        assert!(at > 0 && at <= 200, "converged_at = {at}");
    }

    #[test]
    fn reversal_flips_the_route_back() {
        let out = run_routing_sim(&SimConfig::regime(Regime::Stationary).with_reversal(200));
        // After step 200 the base costs swap, so dtANS is best again.
        assert_eq!(out.best_arm, dtans());
        assert_eq!(out.final_incumbent, dtans());
        assert_eq!(out.flips.len(), 2, "out then back: {:?}", out.flips);
        assert_eq!((out.flips[0].from, out.flips[0].to), (dtans(), csr()));
        assert_eq!((out.flips[1].from, out.flips[1].to), (csr(), dtans()));
        assert!(out.flips[1].at_observation > 200, "the flip-back reacts to the reversal");
    }

    #[test]
    fn drifting_incumbent_is_abandoned_exactly_once() {
        let out = run_routing_sim(&SimConfig::regime(Regime::Drifting));
        assert_eq!(out.best_arm, csr());
        assert_eq!(out.final_incumbent, csr());
        // The incumbent starts genuinely best; one flip once the drift
        // crosses the hysteresis margin, and no churn after.
        assert_eq!(out.flips.len(), 1, "{:?}", out.flips);
        let at = out.flips[0].at_observation;
        assert!(at > 100, "no premature flip while the incumbent still wins (at = {at})");
    }

    #[test]
    fn bimodal_noise_is_bounded_to_two_flips() {
        let out = run_routing_sim(&SimConfig::regime(Regime::BimodalNoisy));
        assert_eq!(out.final_incumbent, csr());
        assert!(out.flips.len() <= 2, "hysteresis must bound flapping: {:?}", out.flips);
        assert!(out.converged_at.is_some());
        // The served p50 after convergence tracks the best static arm.
        assert!(
            out.post_convergence_p50_us <= out.best_static_p50_us * 1.10,
            "post-convergence p50 {} vs best static {}",
            out.post_convergence_p50_us,
            out.best_static_p50_us
        );
    }

    #[test]
    fn exploration_conservation_holds_over_the_whole_trace() {
        let cfg = SimConfig::regime(Regime::Stationary);
        let out = run_routing_sim(&cfg);
        assert_eq!(out.counters.routed, cfg.steps);
        assert_eq!(out.counters.explored + out.counters.exploited, out.counters.routed);
        assert!(out.counters.explored > 0, "ε = 0.2 must actually explore");
        assert_eq!(out.counters.flips, out.flips.len() as u64);
        assert_eq!(out.decisions.len() as u64, cfg.steps);
    }

    #[test]
    fn zero_exploration_is_deterministic_and_flip_free() {
        let mut cfg = SimConfig::regime(Regime::Stationary);
        cfg.adaptive = AdaptiveConfig::zero_exploration();
        let a = run_routing_sim(&cfg);
        let b = run_routing_sim(&cfg);
        assert_eq!(a, b, "seeded simulator must be bit-reproducible");
        assert!(a.flips.is_empty(), "no exploration ⇒ no challenger data ⇒ no flips");
        assert_eq!(a.counters.explored, 0);
        assert!(a.decisions.iter().all(|d| *d == dtans()), "every request rides the static arm");
        // The static choice is hostile here, so the run never converges
        // onto the best arm — which is exactly the point of ε > 0.
        assert_eq!(a.converged_at, None);
    }

    #[test]
    fn challenger_inside_the_margin_never_flips() {
        // 5% better than the incumbent, against a 10% margin: hysteresis
        // must hold the line no matter how long the trace runs.
        let mut cfg = SimConfig::regime(Regime::Stationary);
        cfg.profiles = vec![
            ArmProfile::flat(dtans(), 300.0, 0.0),
            ArmProfile::flat(csr(), 285.0, 0.0),
        ];
        cfg.adaptive.explore_fraction = 0.3;
        cfg.steps = 300;
        let out = run_routing_sim(&cfg);
        assert!(out.flips.is_empty(), "{:?}", out.flips);
        assert_eq!(out.final_incumbent, dtans());
        assert!(out.counters.explored > 0);
    }

    #[test]
    fn oracle_streams_are_per_arm_and_interleaving_independent() {
        let profiles =
            vec![ArmProfile::flat(dtans(), 400.0, 50.0), ArmProfile::flat(csr(), 250.0, 50.0)];
        // Stream A: sample only dtANS.
        let mut solo = LatencyOracle::new(&profiles, 7, None);
        let alone: Vec<f64> = (0..16).map(|s| solo.sample(dtans(), s)).collect();
        // Stream B: interleave CSR draws between every dtANS draw.
        let mut mixed = LatencyOracle::new(&profiles, 7, None);
        let interleaved: Vec<f64> = (0..16)
            .map(|s| {
                let _ = mixed.sample(csr(), s);
                mixed.sample(dtans(), s)
            })
            .collect();
        assert_eq!(alone, interleaved, "an arm's k-th sample must not depend on other arms");
    }
}
