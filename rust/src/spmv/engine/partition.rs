//! nnz-balanced work partitioning.
//!
//! The paper assigns one warp per 32-row slice; throughput then depends on
//! the *nonzeros* (equivalently, stream words) each warp owns, not the row
//! count — the same observation behind row-grouped CSR (Oberhuber et al.,
//! arXiv:1012.2270) and nmSPARSE's balanced partitions. This module
//! reproduces that assignment on the CPU: given a monotone cost-prefix
//! array (CSR's `row_ptr`, a slice word-offset table, SELL's `slice_ptr`),
//! it binary-searches for split points that give every block an equal share
//! of the total cost.
//!
//! Blocks are contiguous, disjoint, and cover every unit exactly once, so
//! a parallel executor can hand each block a disjoint `&mut` range of the
//! output vector and each row is still computed by exactly one serial
//! kernel invocation — which is what makes the parallel results
//! *bit-identical* to the serial ones (see `tests/engine_parallel.rs`).

use crate::format::csr_dtans::CsrDtans;
use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;

/// One contiguous block of work units (rows or slices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block {
    /// First unit (inclusive).
    pub start: usize,
    /// Last unit (exclusive).
    pub end: usize,
    /// Total cost of the block (`prefix[end] - prefix[start]`).
    pub cost: usize,
}

impl Block {
    /// Number of units in the block.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the block spans no units (never produced by the
    /// partitioner; useful for callers building blocks by hand).
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Split `prefix.len() - 1` work units into at most `parts` contiguous
/// blocks of near-equal cost.
///
/// `prefix` is a monotone non-decreasing cost prefix over the units
/// (`prefix[i+1] - prefix[i]` = cost of unit `i`), e.g. CSR's `row_ptr`.
/// For each split `p`, the boundary is the first unit index whose prefix
/// reaches `total * p / parts` — a binary search (`partition_point`),
/// mirroring the paper's equal-nonzeros warp assignment.
///
/// Guarantees (property-tested in `tests/engine_parallel.rs`):
///
/// * blocks are non-empty, contiguous, in ascending order, and cover
///   `0..units` exactly;
/// * block costs sum to `prefix[units] - prefix[0]`;
/// * every block's cost is at most `ceil(total / parts)` plus the largest
///   single-unit cost (a single unit is never split).
///
/// Returns fewer than `parts` blocks when there are fewer units than
/// parts, and an empty vector when there are no units at all.
///
/// ```
/// use dtans::spmv::engine::partition_prefix;
/// // 4 rows with 2, 8, 1, 1 nonzeros: the two-way split lands right
/// // after the heavy row (first boundary whose prefix reaches the
/// // 6-nonzeros target), not at the midpoint row count.
/// let blocks = partition_prefix(&[0, 2, 10, 11, 12], 2);
/// assert_eq!(blocks.len(), 2);
/// assert_eq!((blocks[0].start, blocks[0].end, blocks[0].cost), (0, 2, 10));
/// assert_eq!((blocks[1].start, blocks[1].end, blocks[1].cost), (2, 4, 2));
/// ```
pub fn partition_prefix(prefix: &[usize], parts: usize) -> Vec<Block> {
    partition_prefix_by(prefix, |&v| v, parts)
}

/// Generic core of [`partition_prefix`]: `cost_of` projects each stored
/// offset to its `usize` cost, so narrower offset tables (e.g. the `u32`
/// slice offsets of CSR-dtANS) partition without a widening copy.
fn partition_prefix_by<T>(prefix: &[T], cost_of: impl Fn(&T) -> usize, parts: usize) -> Vec<Block> {
    assert!(!prefix.is_empty(), "prefix must contain at least one offset");
    debug_assert!(
        prefix.windows(2).all(|w| cost_of(&w[0]) <= cost_of(&w[1])),
        "prefix not monotone"
    );
    let units = prefix.len() - 1;
    if units == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, units);
    let base = cost_of(&prefix[0]);
    let total = cost_of(&prefix[units]) - base;
    let mut blocks = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 1..=parts {
        if start == units {
            break;
        }
        let end = if p == parts {
            units
        } else {
            let target = base + ((total as u128 * p as u128) / parts as u128) as usize;
            // First unit boundary at or past the target cost; forced to
            // advance at least one unit so every block is non-empty.
            prefix
                .partition_point(|v| cost_of(v) < target)
                .clamp(start + 1, units)
        };
        blocks.push(Block {
            start,
            end,
            cost: cost_of(&prefix[end]) - cost_of(&prefix[start]),
        });
        start = end;
    }
    blocks
}

/// Partition a CSR matrix's rows into `parts` equal-nonzeros blocks
/// (units = rows, cost = per-row nnz from `row_ptr`).
pub fn partition_csr(m: &Csr, parts: usize) -> Vec<Block> {
    partition_prefix(&m.row_ptr, parts)
}

/// Partition a CSR-dtANS matrix's 32-row slices into `parts` blocks of
/// near-equal *stream words* (units = slices, cost = encoded words, the
/// quantity that actually bounds decode time). Slices are the kernel's
/// atomic unit, so blocks always align to `WARP`-row boundaries.
pub fn partition_dtans(m: &CsrDtans, parts: usize) -> Vec<Block> {
    partition_prefix_by(&m.slice_offsets, |&w| w as usize, parts)
}

/// Partition a SELL matrix's slices into `parts` blocks of near-equal
/// *padded cells* (units = slices, cost = `slice_ptr` deltas — padding is
/// real work in the SELL kernel, so it is what must balance).
pub fn partition_sell(m: &Sell, parts: usize) -> Vec<Block> {
    partition_prefix(&m.slice_ptr, parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;

    fn assert_valid(blocks: &[Block], prefix: &[usize], parts: usize) {
        let units = prefix.len() - 1;
        if units == 0 {
            assert!(blocks.is_empty());
            return;
        }
        let total = prefix[units] - prefix[0];
        assert!(!blocks.is_empty());
        assert!(blocks.len() <= parts.clamp(1, units));
        assert_eq!(blocks[0].start, 0);
        assert_eq!(blocks.last().unwrap().end, units);
        let max_unit = prefix.windows(2).map(|w| w[1] - w[0]).max().unwrap();
        let mut expect_start = 0;
        let mut cost_sum = 0;
        for b in blocks {
            assert_eq!(b.start, expect_start, "blocks not contiguous");
            assert!(b.end > b.start, "empty block");
            assert_eq!(b.cost, prefix[b.end] - prefix[b.start]);
            assert!(
                b.cost <= total.div_ceil(parts.clamp(1, units)) + max_unit,
                "unbalanced block {b:?} (total {total}, parts {parts})"
            );
            expect_start = b.end;
            cost_sum += b.cost;
        }
        assert_eq!(cost_sum, total);
    }

    #[test]
    fn uniform_costs_split_evenly() {
        let prefix: Vec<usize> = (0..=100).map(|i| i * 5).collect();
        for parts in [1, 2, 3, 4, 7, 16, 100] {
            let blocks = partition_prefix(&prefix, parts);
            assert_eq!(blocks.len(), parts.min(100));
            assert_valid(&blocks, &prefix, parts);
        }
    }

    #[test]
    fn skewed_costs_balance_by_cost_not_rows() {
        // One huge row at the front: it must sit alone in the first block.
        let prefix = vec![0, 1000, 1001, 1002, 1003, 1004];
        let blocks = partition_prefix(&prefix, 2);
        assert_valid(&blocks, &prefix, 2);
        assert_eq!(blocks[0], Block { start: 0, end: 1, cost: 1000 });
        assert_eq!(blocks[1], Block { start: 1, end: 5, cost: 4 });
    }

    #[test]
    fn zero_cost_units_are_still_covered() {
        // All-empty rows: every unit must land in some block.
        let prefix = vec![0usize; 9]; // 8 rows, 0 nnz
        for parts in 1..=16 {
            let blocks = partition_prefix(&prefix, parts);
            assert_valid(&blocks, &prefix, parts);
        }
    }

    #[test]
    fn fewer_units_than_parts() {
        let prefix = vec![0, 3, 7];
        let blocks = partition_prefix(&prefix, 16);
        assert_valid(&blocks, &prefix, 16);
        assert_eq!(blocks.len(), 2);
    }

    #[test]
    fn no_units_yields_no_blocks() {
        assert!(partition_prefix(&[0], 4).is_empty());
        assert!(partition_prefix(&[42], 1).is_empty());
    }

    #[test]
    fn csr_partition_matches_row_ptr() {
        let mut coo = Coo::new(4, 4);
        for &(r, c) in &[(0, 0), (0, 1), (1, 0), (1, 1), (1, 2), (2, 0), (3, 3)] {
            coo.push(r, c, 1.0);
        }
        let m = Csr::from_coo(&coo);
        let blocks = partition_csr(&m, 2);
        assert_valid(&blocks, &m.row_ptr, 2);
        assert_eq!(blocks.iter().map(|b| b.cost).sum::<usize>(), m.nnz());
    }
}
