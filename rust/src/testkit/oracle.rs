//! Differential conformance oracle: every format × every strategy ×
//! every kernel variant, against the serial CSR ground truth.
//!
//! Two levels of agreement are checked for each operator the
//! [`FormatRegistry`] can build, for each swept
//! [`KernelVariant`]:
//!
//! 1. **Cross-format closeness** — the operator's serial result *under
//!    the variant* must match the serial scalar CSR free-function kernel
//!    ([`spmv_csr`](crate::spmv::spmv_csr)) within
//!    [`OracleConfig::rel_tol`]. Exact bit-identity is *not* required
//!    across formats or variants: COO's scatter order, the dtANS lockstep
//!    decoder and the unrolled wide-accumulator kernels all reassociate
//!    row sums (see `docs/SOLVERS.md` §format-independence and
//!    `docs/KERNELS.md`), so the guarantee across formats/variants is
//!    tight closeness, not equality.
//! 2. **Engine bit-identity per variant** — for every partition count
//!    `Fixed(1..=max_parts)`, the engine's result over the operator under
//!    the variant must be **bit-identical** to the operator's own serial
//!    result *under the same variant*. This is the repo-wide invariant
//!    the engine is built on (each row computed by exactly one block,
//!    with per-row arithmetic that depends only on the row — never on
//!    block boundaries), checked here exhaustively instead of per-format
//!    ad hoc.
//!
//! The default entry points ([`check_matrix`], [`check_matrix_with`],
//! [`check_operator`]) sweep the scalar variant only — the historical
//! behavior; [`cross_check_with`] and [`check_operator_with`] take an
//! explicit variant list (usually [`KernelVariant::ALL`]) and an explicit
//! registry, so custom-registered formats and non-default variants are
//! swept too (`tests/conformance.rs` uses them).
//!
//! Failures come back as structured [`Mismatch`] records — format tag,
//! kernel variant, partition count, first divergent row, the two values
//! and their ULP distance — so a conformance break is immediately
//! actionable. [`PerturbedOperator`] is the oracle's own negative
//! control: it wraps any operator and flips one output bit only on
//! partitioned runs, which a healthy oracle must detect and localize
//! (`tests/conformance.rs`). [`MiscombinedOperator`] is the
//! reassociation-drift control: it answers partitioned blocks with a
//! deliberately *wrong combine order* (reverse-order row folds), proving
//! the per-variant bit-identity level can actually catch a kernel whose
//! partitioned arithmetic silently reassociates.

use crate::format::csr_dtans::EncodeOptions;
use crate::matrix::csr::Csr;
use crate::matrix::Precision;
use crate::spmv::densemat::{DenseMat, DenseMatMut};
use crate::spmv::engine::{Block, KernelVariant, ParStrategy, SpmvEngine};
use crate::spmv::operator::{FormatRegistry, SpmvOperator};
use crate::testkit::seeded_vector as input_vector;
use crate::util::error::Result;
use std::borrow::Cow;
use std::fmt;
use std::sync::Arc;

/// Oracle knobs.
#[derive(Debug, Clone)]
pub struct OracleConfig {
    /// Encoding options for the dtANS (and precision-sensitive) builders.
    pub opts: EncodeOptions,
    /// Highest `ParStrategy::Fixed(n)` partition count swept (each of
    /// `1..=max_parts` is checked for bit-identity).
    pub max_parts: usize,
    /// Allowed elementwise relative error against the CSR ground truth
    /// (`|a-b| / max(1, |a|, |b|)` — the [`crate::spmv::verify`] metric).
    pub rel_tol: f64,
    /// Seed for the multiply's input vector.
    pub seed: u64,
}

impl Default for OracleConfig {
    fn default() -> Self {
        OracleConfig {
            opts: EncodeOptions::default(),
            max_parts: 8,
            rel_tol: 1e-9,
            seed: 0xD7A5,
        }
    }
}

/// Which oracle level a mismatch violated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MismatchKind {
    /// The operator's serial result diverged from the serial CSR ground
    /// truth beyond [`OracleConfig::rel_tol`].
    CrossFormat,
    /// A partitioned engine run was not bit-identical to the operator's
    /// own serial result.
    ParallelDivergence,
}

/// One detected divergence: where, under what execution, by how much.
#[derive(Debug, Clone)]
pub struct Mismatch {
    /// Violated oracle level.
    pub kind: MismatchKind,
    /// [`SpmvOperator::format_tag`] of the offending operator.
    pub format: &'static str,
    /// Kernel variant the offending run executed under.
    pub variant: KernelVariant,
    /// Partition count of the offending run (0 for the serial
    /// cross-format check, which has no partitioning).
    pub parts: usize,
    /// First divergent output row (worst row for cross-format checks).
    pub row: usize,
    /// Value the offending run produced at `row`.
    pub got: f64,
    /// Value the reference produced at `row`.
    pub want: f64,
    /// Bit-pattern distance between `got` and `want` (1 = adjacent
    /// floats; large values indicate sign/exponent damage).
    pub ulps: u64,
}

impl fmt::Display for Mismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let level = match self.kind {
            MismatchKind::CrossFormat => "cross-format (vs serial CSR)".to_string(),
            MismatchKind::ParallelDivergence => {
                format!("partition divergence (parts={})", self.parts)
            }
        };
        write!(
            f,
            "[{}/{}] {level}: row {} got {:e} want {:e} ({} ulp)",
            self.format,
            self.variant.label(),
            self.row,
            self.got,
            self.want,
            self.ulps
        )
    }
}

/// What one conformance run covered and what it found.
#[derive(Debug, Default)]
pub struct ConformanceReport {
    /// Format tags that were built and checked.
    pub formats: Vec<&'static str>,
    /// Format tags whose builder refused this matrix (e.g. the dense
    /// oracle above its cell cap) — skipped, as the registry contract
    /// allows.
    pub skipped: Vec<&'static str>,
    /// Execution strategies swept per format (serial + each `Fixed(n)`).
    pub strategies: usize,
    /// Every detected divergence, in detection order.
    pub mismatches: Vec<Mismatch>,
}

impl ConformanceReport {
    /// True when no mismatch was detected.
    pub fn is_conformant(&self) -> bool {
        self.mismatches.is_empty()
    }
}

impl fmt::Display for ConformanceReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} formats x {} strategies, {} skipped, {} mismatch(es)",
            self.formats.len(),
            self.strategies,
            self.skipped.len(),
            self.mismatches.len()
        )?;
        for m in &self.mismatches {
            write!(f, "\n  {m}")?;
        }
        Ok(())
    }
}

/// Bit-pattern distance between two doubles (0 iff identical bits).
pub fn ulp_distance(a: f64, b: f64) -> u64 {
    (a.to_bits() as i64).abs_diff(b.to_bits() as i64)
}

/// Run the full conformance sweep on one matrix with the built-in
/// registry. See [`check_matrix_with`] for the sweep definition.
///
/// ```
/// use dtans::matrix::gen::structured::banded;
/// use dtans::testkit::oracle::{check_matrix, OracleConfig};
///
/// let report = check_matrix(&banded(100, 2), &OracleConfig::default()).unwrap();
/// assert!(report.is_conformant(), "{report}");
/// assert!(report.formats.contains(&"csr_dtans"));
/// ```
pub fn check_matrix(m: &Csr, cfg: &OracleConfig) -> Result<ConformanceReport> {
    check_matrix_with(m, cfg, &FormatRegistry::builtin())
}

/// Run the conformance sweep on one matrix over an explicit registry
/// (tests shadow entries with deliberately perturbed builders to prove
/// the oracle detects them).
///
/// The matrix is first rounded to the configured precision (encoders
/// round internally; the reference must match), then the serial CSR
/// kernel produces the ground truth and every registry operator is swept
/// through the two oracle levels described in the [module docs](self).
pub fn check_matrix_with(
    m: &Csr,
    cfg: &OracleConfig,
    registry: &FormatRegistry,
) -> Result<ConformanceReport> {
    cross_check_with(m, cfg, registry, &[KernelVariant::Scalar])
}

/// The full cross-product sweep: every format the registry can build ×
/// every listed [`KernelVariant`] × serial + every partition count.
/// This is the latent-gap fix for custom-registered formats and
/// non-default variants: [`check_matrix`] / [`check_matrix_with`] are the
/// builtin-registry / scalar-only specializations of this entry point.
///
/// Ground truth stays the *scalar* serial CSR kernel for every variant —
/// the two-level contract is closeness to scalar CSR (level 1) plus
/// per-variant partition bit-identity (level 2); see `docs/KERNELS.md`.
///
/// ```
/// use dtans::matrix::gen::structured::banded;
/// use dtans::spmv::engine::KernelVariant;
/// use dtans::spmv::operator::FormatRegistry;
/// use dtans::testkit::oracle::{cross_check_with, OracleConfig};
///
/// let report = cross_check_with(
///     &banded(100, 2),
///     &OracleConfig::default(),
///     &FormatRegistry::builtin(),
///     &KernelVariant::ALL,
/// )
/// .unwrap();
/// assert!(report.is_conformant(), "{report}");
/// assert!(report.formats.contains(&"blocked_ell"));
/// assert_eq!(report.strategies, 3 * 9); // 3 variants x (serial + Fixed(1..=8))
/// ```
pub fn cross_check_with(
    m: &Csr,
    cfg: &OracleConfig,
    registry: &FormatRegistry,
    variants: &[KernelVariant],
) -> Result<ConformanceReport> {
    let reference = match cfg.opts.precision {
        Precision::F64 => m.clone(),
        Precision::F32 => m.round_to_f32(),
    };
    let x = input_vector(m.ncols, cfg.seed);
    let mut want = vec![0.0; m.nrows];
    crate::spmv::csr::spmv_csr(&reference, &x, &mut want)?;

    let mut report = ConformanceReport {
        strategies: variants.len() * (cfg.max_parts.max(1) + 1),
        ..Default::default()
    };
    for (tag, op) in registry.build_all(&reference, &cfg.opts) {
        match op {
            Ok(op) => {
                report.formats.push(tag);
                check_one(op.as_ref(), &x, &want, cfg, variants, &mut report)?;
            }
            Err(_) => report.skipped.push(tag),
        }
    }
    Ok(report)
}

/// Conformance-check a single operator against a CSR reference matrix
/// (the entry point for hand-built operators such as
/// [`PerturbedOperator`]), scalar variant only. `reference` must already
/// be at the operator's precision.
pub fn check_operator(
    op: &dyn SpmvOperator,
    reference: &Csr,
    cfg: &OracleConfig,
) -> Result<ConformanceReport> {
    check_operator_with(op, reference, cfg, &[KernelVariant::Scalar])
}

/// [`check_operator`] over an explicit variant list — sweeps the single
/// operator under every listed [`KernelVariant`].
pub fn check_operator_with(
    op: &dyn SpmvOperator,
    reference: &Csr,
    cfg: &OracleConfig,
    variants: &[KernelVariant],
) -> Result<ConformanceReport> {
    let x = input_vector(reference.ncols, cfg.seed);
    let mut want = vec![0.0; reference.nrows];
    crate::spmv::csr::spmv_csr(reference, &x, &mut want)?;
    let mut report = ConformanceReport {
        formats: vec![op.format_tag()],
        strategies: variants.len() * (cfg.max_parts.max(1) + 1),
        ..Default::default()
    };
    check_one(op, &x, &want, cfg, variants, &mut report)?;
    Ok(report)
}

/// The per-operator sweep shared by [`cross_check_with`] and
/// [`check_operator_with`]: both oracle levels, once per variant.
fn check_one(
    op: &dyn SpmvOperator,
    x: &[f64],
    want: &[f64],
    cfg: &OracleConfig,
    variants: &[KernelVariant],
    report: &mut ConformanceReport,
) -> Result<()> {
    let tag = op.format_tag();
    let nrows = want.len();

    for &variant in variants {
        // Level 1: the operator's own serial result under this variant vs
        // the scalar CSR ground truth (closeness, not bit-identity —
        // formats and variants may reassociate).
        let mut own = vec![0.0; nrows];
        SpmvEngine::serial().with_kernel_variant(variant).run(op, x, &mut own)?;
        let mut worst: Option<(usize, f64)> = None;
        for (i, (&got, &w)) in own.iter().zip(want).enumerate() {
            let rel = (got - w).abs() / got.abs().max(w.abs()).max(1.0);
            let beats = match worst {
                None => true,
                Some((_, r)) => rel > r,
            };
            if rel > cfg.rel_tol && beats {
                worst = Some((i, rel));
            }
        }
        if let Some((row, _)) = worst {
            report.mismatches.push(Mismatch {
                kind: MismatchKind::CrossFormat,
                format: tag,
                variant,
                parts: 0,
                row,
                got: own[row],
                want: want[row],
                ulps: ulp_distance(own[row], want[row]),
            });
        }

        // Level 2: every partition count vs the operator's own serial
        // result under the same variant, bit for bit.
        for parts in 1..=cfg.max_parts.max(1) {
            let engine =
                SpmvEngine::new(ParStrategy::Fixed(parts)).with_kernel_variant(variant);
            let mut got = vec![0.0; nrows];
            engine.run(op, x, &mut got)?;
            if let Some(row) = (0..nrows).find(|&r| got[r].to_bits() != own[r].to_bits()) {
                report.mismatches.push(Mismatch {
                    kind: MismatchKind::ParallelDivergence,
                    format: tag,
                    variant,
                    parts,
                    row,
                    got: got[row],
                    want: own[row],
                    ulps: ulp_distance(got[row], own[row]),
                });
            }
        }
    }
    Ok(())
}

/// A deliberately faulty operator — the oracle's negative control.
///
/// Delegates everything to the wrapped operator, but flips the lowest
/// mantissa bit of output row `row` on every block-level run that is
/// *not* the full serial block. A serial run therefore stays clean while
/// every partitioned run diverges by exactly 1 ULP at `row` — the
/// smallest possible conformance break, which the oracle must still
/// detect and localize (format tag, partition count, row). Used by the
/// negative self-tests in `tests/conformance.rs`.
pub struct PerturbedOperator {
    inner: Arc<dyn SpmvOperator>,
    row: usize,
}

impl PerturbedOperator {
    /// Wrap `inner`, targeting output row `row` (must be in range).
    pub fn new(inner: Arc<dyn SpmvOperator>, row: usize) -> PerturbedOperator {
        assert!(row < inner.dims().0, "perturbed row out of range");
        PerturbedOperator { inner, row }
    }

    /// Flip the target row's entry iff this block is a partitioned run
    /// (i.e. not the single full-range block the serial path uses).
    fn perturb(&self, block: Block, y_seg: &mut [f64]) {
        let units = self.inner.cost_prefix().len().saturating_sub(1);
        if block.start == 0 && block.end == units {
            return; // full serial block: stay clean
        }
        let r0 = self.inner.rows_through(block.start);
        let r1 = self.inner.rows_through(block.end);
        if (r0..r1).contains(&self.row) {
            let y = &mut y_seg[self.row - r0];
            *y = f64::from_bits(y.to_bits() ^ 1);
        }
    }
}

impl SpmvOperator for PerturbedOperator {
    fn dims(&self) -> (usize, usize) {
        self.inner.dims()
    }

    fn nnz(&self) -> usize {
        self.inner.nnz()
    }

    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        self.inner.cost_prefix()
    }

    fn cost(&self) -> usize {
        self.inner.cost()
    }

    fn rows_through(&self, unit_end: usize) -> usize {
        self.inner.rows_through(unit_end)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        self.inner.run_range(block, x, y_seg)?;
        self.perturb(block, y_seg);
        Ok(())
    }

    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        self.inner.run_range_axpby(block, x, alpha, beta, y_seg)?;
        self.perturb(block, y_seg);
        Ok(())
    }

    fn run_range_multi(&self, block: Block, xs: &DenseMat, ys: &mut DenseMatMut<'_>) -> Result<()> {
        self.inner.run_range_multi(block, xs, ys)?;
        for j in 0..ys.ncols() {
            self.perturb(block, ys.col_mut(j));
        }
        Ok(())
    }

    // The variant hooks must forward to the *inner* operator's variant
    // dispatch (not fall back to the trait defaults, which would reroute
    // through our own `run_range` and lose the variant), then perturb —
    // so the negative control stays honest under variant sweeps.
    fn run_range_variant(
        &self,
        block: Block,
        x: &[f64],
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        self.inner.run_range_variant(block, x, y_seg, variant)?;
        self.perturb(block, y_seg);
        Ok(())
    }

    fn run_range_axpby_variant(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        self.inner.run_range_axpby_variant(block, x, alpha, beta, y_seg, variant)?;
        self.perturb(block, y_seg);
        Ok(())
    }

    fn run_range_multi_variant(
        &self,
        block: Block,
        xs: &DenseMat,
        ys: &mut DenseMatMut<'_>,
        variant: KernelVariant,
    ) -> Result<()> {
        self.inner.run_range_multi_variant(block, xs, ys, variant)?;
        for j in 0..ys.ncols() {
            self.perturb(block, ys.col_mut(j));
        }
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }

    fn format_tag(&self) -> &'static str {
        self.inner.format_tag()
    }
}

/// A CSR operator with a deliberately *wrong combine order* — the
/// oracle's reassociation-drift negative control.
///
/// On the full serial block it runs the correct scalar CSR kernel. On any
/// partitioned block it computes each row's dot product by a
/// **reverse-element-order sequential fold** instead. Floating-point
/// addition is commutative bit-for-bit but not associative, so the
/// reversed *sequential* fold genuinely changes the association — e.g.
/// with products `[1.0, 2⁻⁵³, 2⁻⁵³, 2⁻⁵³]` the forward fold yields
/// `1 + 2⁻⁵²` while the reverse fold yields `1 + 2⁻⁵¹`. A healthy oracle
/// must flag this as [`MismatchKind::ParallelDivergence`]: the partitioned
/// result is no longer bit-identical to the serial result, which is
/// exactly the bug class the level-2 check exists to catch (a kernel whose
/// partitioned arithmetic silently reassociates row sums). Used by
/// `tests/kernel_variants.rs`.
pub struct MiscombinedOperator {
    inner: Arc<Csr>,
}

impl MiscombinedOperator {
    /// Wrap a CSR matrix.
    pub fn new(inner: Arc<Csr>) -> MiscombinedOperator {
        MiscombinedOperator { inner }
    }

    /// One row's dot product folded back-to-front — a different
    /// association than the forward fold the scalar kernel uses.
    fn row_dot_reversed(&self, r: usize, x: &[f64]) -> f64 {
        let m = &*self.inner;
        let (lo, hi) = (m.row_ptr[r], m.row_ptr[r + 1]);
        let mut acc = 0.0;
        for k in (lo..hi).rev() {
            acc += m.vals[k] * x[m.cols[k] as usize];
        }
        acc
    }

    fn is_full_block(&self, block: Block) -> bool {
        let units = self.inner.cost_prefix().len().saturating_sub(1);
        block.start == 0 && block.end == units
    }
}

impl SpmvOperator for MiscombinedOperator {
    fn dims(&self) -> (usize, usize) {
        self.inner.dims()
    }

    fn nnz(&self) -> usize {
        Csr::nnz(&self.inner)
    }

    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        self.inner.cost_prefix()
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        if self.is_full_block(block) {
            return crate::spmv::csr::spmv_row_range(&self.inner, block.start, block.end, x, y_seg);
        }
        for r in block.start..block.end {
            y_seg[r - block.start] += self.row_dot_reversed(r, x);
        }
        Ok(())
    }

    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        if self.is_full_block(block) {
            return crate::spmv::csr::spmv_row_range_axpby(
                &self.inner,
                block.start,
                block.end,
                x,
                alpha,
                beta,
                y_seg,
            );
        }
        for r in block.start..block.end {
            let y = &mut y_seg[r - block.start];
            *y = alpha * self.row_dot_reversed(r, x) + beta * *y;
        }
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }

    fn format_tag(&self) -> &'static str {
        "csr" // masquerades as a CSR kernel — that's the point
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample() -> Csr {
        let mut m = banded(150, 3);
        assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(3));
        m
    }

    #[test]
    fn healthy_matrix_is_conformant_across_all_formats() {
        let report = check_matrix(&sample(), &OracleConfig::default()).unwrap();
        assert!(report.is_conformant(), "{report}");
        assert_eq!(report.formats.len() + report.skipped.len(), 6);
        assert!(report.formats.contains(&"csr"));
        assert!(report.formats.contains(&"blocked_ell"));
        assert!(report.formats.contains(&"csr_dtans"));
        assert_eq!(report.strategies, 9); // 1 variant x (serial + Fixed(1..=8))
    }

    #[test]
    fn perturbed_operator_is_detected_with_partition_and_row() {
        let m = sample();
        let bad = PerturbedOperator::new(Arc::new(m.clone()), 37);
        let report = check_operator(&bad, &m, &OracleConfig::default()).unwrap();
        assert!(!report.is_conformant());
        // Serial and Fixed(1) runs are clean (no pool, full block), so the
        // first detection is the 2-way partition; every larger partition
        // count re-detects it.
        let first = &report.mismatches[0];
        assert_eq!(first.kind, MismatchKind::ParallelDivergence);
        assert_eq!(first.format, "csr");
        assert_eq!(first.parts, 2);
        assert_eq!(first.row, 37);
        assert_eq!(first.ulps, 1);
        assert_eq!(report.mismatches.len(), 7); // parts 2..=8
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(1.0, f64::from_bits(1.0f64.to_bits() + 1)), 1);
        assert!(ulp_distance(1.0, -1.0) > 1 << 60);
    }

    #[test]
    fn mismatch_display_is_informative() {
        let m = Mismatch {
            kind: MismatchKind::ParallelDivergence,
            format: "sell",
            variant: KernelVariant::Unrolled4,
            parts: 4,
            row: 9,
            got: 1.0,
            want: 2.0,
            ulps: 42,
        };
        let s = m.to_string();
        assert!(
            s.contains("sell")
                && s.contains("unrolled4")
                && s.contains("parts=4")
                && s.contains("row 9"),
            "{s}"
        );
    }

    #[test]
    fn miscombined_operator_is_flagged_as_parallel_divergence() {
        // Precondition: under the oracle's own input vector, at least one
        // row's forward and reverse folds must differ bitwise — otherwise
        // the control would be vacuous on this fixture.
        let m = sample();
        let cfg = OracleConfig::default();
        let x = input_vector(m.ncols, cfg.seed);
        let bad = MiscombinedOperator::new(Arc::new(m.clone()));
        let differs = (0..m.nrows).any(|r| {
            let fwd: f64 = (m.row_ptr[r]..m.row_ptr[r + 1])
                .fold(0.0, |acc, k| acc + m.vals[k] * x[m.cols[k] as usize]);
            fwd.to_bits() != bad.row_dot_reversed(r, &x).to_bits()
        });
        assert!(differs, "fixture too tame: reverse fold never changes a bit");

        let report = check_operator(&bad, &m, &cfg).unwrap();
        assert!(!report.is_conformant());
        // Serial and Fixed(1) are the full block (correct kernel); every
        // genuinely partitioned run must be caught at level 2.
        assert!(report
            .mismatches
            .iter()
            .all(|mm| mm.kind == MismatchKind::ParallelDivergence && mm.parts >= 2));
        assert_eq!(report.mismatches.len(), 7); // parts 2..=8
    }
}
