//! Differential conformance oracle: every format × every strategy,
//! against the serial CSR ground truth.
//!
//! Two levels of agreement are checked for each operator the
//! [`FormatRegistry`] can build:
//!
//! 1. **Cross-format closeness** — the operator's serial result must match
//!    the serial CSR free-function kernel
//!    ([`spmv_csr`](crate::spmv::spmv_csr)) within
//!    [`OracleConfig::rel_tol`]. Exact bit-identity is *not* required
//!    across formats: COO's scatter order and the dtANS lockstep decoder
//!    reassociate row sums (see `docs/SOLVERS.md` §format-independence),
//!    so the guarantee across formats is tight closeness, not equality.
//! 2. **Engine bit-identity** — for every partition count
//!    `Fixed(1..=max_parts)`, the engine's result over the operator must
//!    be **bit-identical** to the operator's own serial result. This is
//!    the repo-wide invariant the engine is built on (each row computed by
//!    exactly one block with the serial kernel's arithmetic), checked here
//!    exhaustively instead of per-format ad hoc.
//!
//! Failures come back as structured [`Mismatch`] records — format tag,
//! partition count, first divergent row, the two values and their ULP
//! distance — so a conformance break is immediately actionable.
//! [`PerturbedOperator`] is the oracle's own negative control: it wraps
//! any operator and flips one output bit only on partitioned runs, which a
//! healthy oracle must detect and localize (`tests/conformance.rs`).

use crate::format::csr_dtans::EncodeOptions;
use crate::matrix::csr::Csr;
use crate::matrix::Precision;
use crate::spmv::densemat::{DenseMat, DenseMatMut};
use crate::spmv::engine::{Block, ParStrategy, SpmvEngine};
use crate::spmv::operator::{FormatRegistry, SpmvOperator};
use crate::testkit::seeded_vector as input_vector;
use crate::util::error::Result;
use std::borrow::Cow;
use std::fmt;
use std::sync::Arc;

/// Oracle knobs.
#[derive(Debug, Clone)]
pub struct OracleConfig {
    /// Encoding options for the dtANS (and precision-sensitive) builders.
    pub opts: EncodeOptions,
    /// Highest `ParStrategy::Fixed(n)` partition count swept (each of
    /// `1..=max_parts` is checked for bit-identity).
    pub max_parts: usize,
    /// Allowed elementwise relative error against the CSR ground truth
    /// (`|a-b| / max(1, |a|, |b|)` — the [`crate::spmv::verify`] metric).
    pub rel_tol: f64,
    /// Seed for the multiply's input vector.
    pub seed: u64,
}

impl Default for OracleConfig {
    fn default() -> Self {
        OracleConfig {
            opts: EncodeOptions::default(),
            max_parts: 8,
            rel_tol: 1e-9,
            seed: 0xD7A5,
        }
    }
}

/// Which oracle level a mismatch violated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MismatchKind {
    /// The operator's serial result diverged from the serial CSR ground
    /// truth beyond [`OracleConfig::rel_tol`].
    CrossFormat,
    /// A partitioned engine run was not bit-identical to the operator's
    /// own serial result.
    ParallelDivergence,
}

/// One detected divergence: where, under what execution, by how much.
#[derive(Debug, Clone)]
pub struct Mismatch {
    /// Violated oracle level.
    pub kind: MismatchKind,
    /// [`SpmvOperator::format_tag`] of the offending operator.
    pub format: &'static str,
    /// Partition count of the offending run (0 for the serial
    /// cross-format check, which has no partitioning).
    pub parts: usize,
    /// First divergent output row (worst row for cross-format checks).
    pub row: usize,
    /// Value the offending run produced at `row`.
    pub got: f64,
    /// Value the reference produced at `row`.
    pub want: f64,
    /// Bit-pattern distance between `got` and `want` (1 = adjacent
    /// floats; large values indicate sign/exponent damage).
    pub ulps: u64,
}

impl fmt::Display for Mismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let level = match self.kind {
            MismatchKind::CrossFormat => "cross-format (vs serial CSR)".to_string(),
            MismatchKind::ParallelDivergence => {
                format!("partition divergence (parts={})", self.parts)
            }
        };
        write!(
            f,
            "[{}] {level}: row {} got {:e} want {:e} ({} ulp)",
            self.format, self.row, self.got, self.want, self.ulps
        )
    }
}

/// What one conformance run covered and what it found.
#[derive(Debug, Default)]
pub struct ConformanceReport {
    /// Format tags that were built and checked.
    pub formats: Vec<&'static str>,
    /// Format tags whose builder refused this matrix (e.g. the dense
    /// oracle above its cell cap) — skipped, as the registry contract
    /// allows.
    pub skipped: Vec<&'static str>,
    /// Execution strategies swept per format (serial + each `Fixed(n)`).
    pub strategies: usize,
    /// Every detected divergence, in detection order.
    pub mismatches: Vec<Mismatch>,
}

impl ConformanceReport {
    /// True when no mismatch was detected.
    pub fn is_conformant(&self) -> bool {
        self.mismatches.is_empty()
    }
}

impl fmt::Display for ConformanceReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} formats x {} strategies, {} skipped, {} mismatch(es)",
            self.formats.len(),
            self.strategies,
            self.skipped.len(),
            self.mismatches.len()
        )?;
        for m in &self.mismatches {
            write!(f, "\n  {m}")?;
        }
        Ok(())
    }
}

/// Bit-pattern distance between two doubles (0 iff identical bits).
pub fn ulp_distance(a: f64, b: f64) -> u64 {
    (a.to_bits() as i64).abs_diff(b.to_bits() as i64)
}

/// Run the full conformance sweep on one matrix with the built-in
/// registry. See [`check_matrix_with`] for the sweep definition.
///
/// ```
/// use dtans::matrix::gen::structured::banded;
/// use dtans::testkit::oracle::{check_matrix, OracleConfig};
///
/// let report = check_matrix(&banded(100, 2), &OracleConfig::default()).unwrap();
/// assert!(report.is_conformant(), "{report}");
/// assert!(report.formats.contains(&"csr_dtans"));
/// ```
pub fn check_matrix(m: &Csr, cfg: &OracleConfig) -> Result<ConformanceReport> {
    check_matrix_with(m, cfg, &FormatRegistry::builtin())
}

/// Run the conformance sweep on one matrix over an explicit registry
/// (tests shadow entries with deliberately perturbed builders to prove
/// the oracle detects them).
///
/// The matrix is first rounded to the configured precision (encoders
/// round internally; the reference must match), then the serial CSR
/// kernel produces the ground truth and every registry operator is swept
/// through the two oracle levels described in the [module docs](self).
pub fn check_matrix_with(
    m: &Csr,
    cfg: &OracleConfig,
    registry: &FormatRegistry,
) -> Result<ConformanceReport> {
    let reference = match cfg.opts.precision {
        Precision::F64 => m.clone(),
        Precision::F32 => m.round_to_f32(),
    };
    let x = input_vector(m.ncols, cfg.seed);
    let mut want = vec![0.0; m.nrows];
    crate::spmv::csr::spmv_csr(&reference, &x, &mut want)?;

    let engines = fixed_engines(cfg.max_parts);
    let mut report = ConformanceReport { strategies: engines.len() + 1, ..Default::default() };
    for (tag, op) in registry.build_all(&reference, &cfg.opts) {
        match op {
            Ok(op) => {
                report.formats.push(tag);
                check_one(op.as_ref(), &x, &want, cfg, &engines, &mut report)?;
            }
            Err(_) => report.skipped.push(tag),
        }
    }
    Ok(report)
}

/// Conformance-check a single operator against a CSR reference matrix
/// (the entry point for hand-built operators such as
/// [`PerturbedOperator`]). `reference` must already be at the operator's
/// precision.
pub fn check_operator(
    op: &dyn SpmvOperator,
    reference: &Csr,
    cfg: &OracleConfig,
) -> Result<ConformanceReport> {
    let x = input_vector(reference.ncols, cfg.seed);
    let mut want = vec![0.0; reference.nrows];
    crate::spmv::csr::spmv_csr(reference, &x, &mut want)?;
    let engines = fixed_engines(cfg.max_parts);
    let mut report = ConformanceReport {
        formats: vec![op.format_tag()],
        strategies: engines.len() + 1,
        ..Default::default()
    };
    check_one(op, &x, &want, cfg, &engines, &mut report)?;
    Ok(report)
}

fn fixed_engines(max_parts: usize) -> Vec<SpmvEngine> {
    (1..=max_parts.max(1)).map(|p| SpmvEngine::new(ParStrategy::Fixed(p))).collect()
}

/// The per-operator sweep shared by [`check_matrix_with`] and
/// [`check_operator`].
fn check_one(
    op: &dyn SpmvOperator,
    x: &[f64],
    want: &[f64],
    cfg: &OracleConfig,
    engines: &[SpmvEngine],
    report: &mut ConformanceReport,
) -> Result<()> {
    let tag = op.format_tag();
    let nrows = want.len();

    // Level 1: the operator's own serial result vs the CSR ground truth.
    let mut own = vec![0.0; nrows];
    SpmvEngine::serial().run(op, x, &mut own)?;
    let mut worst: Option<(usize, f64)> = None;
    for (i, (&got, &w)) in own.iter().zip(want).enumerate() {
        let rel = (got - w).abs() / got.abs().max(w.abs()).max(1.0);
        let beats = match worst {
            None => true,
            Some((_, r)) => rel > r,
        };
        if rel > cfg.rel_tol && beats {
            worst = Some((i, rel));
        }
    }
    if let Some((row, _)) = worst {
        report.mismatches.push(Mismatch {
            kind: MismatchKind::CrossFormat,
            format: tag,
            parts: 0,
            row,
            got: own[row],
            want: want[row],
            ulps: ulp_distance(own[row], want[row]),
        });
    }

    // Level 2: every partition count vs the operator's own serial result,
    // bit for bit.
    for (i, engine) in engines.iter().enumerate() {
        let parts = i + 1;
        let mut got = vec![0.0; nrows];
        engine.run(op, x, &mut got)?;
        if let Some(row) = (0..nrows).find(|&r| got[r].to_bits() != own[r].to_bits()) {
            report.mismatches.push(Mismatch {
                kind: MismatchKind::ParallelDivergence,
                format: tag,
                parts,
                row,
                got: got[row],
                want: own[row],
                ulps: ulp_distance(got[row], own[row]),
            });
        }
    }
    Ok(())
}

/// A deliberately faulty operator — the oracle's negative control.
///
/// Delegates everything to the wrapped operator, but flips the lowest
/// mantissa bit of output row `row` on every block-level run that is
/// *not* the full serial block. A serial run therefore stays clean while
/// every partitioned run diverges by exactly 1 ULP at `row` — the
/// smallest possible conformance break, which the oracle must still
/// detect and localize (format tag, partition count, row). Used by the
/// negative self-tests in `tests/conformance.rs`.
pub struct PerturbedOperator {
    inner: Arc<dyn SpmvOperator>,
    row: usize,
}

impl PerturbedOperator {
    /// Wrap `inner`, targeting output row `row` (must be in range).
    pub fn new(inner: Arc<dyn SpmvOperator>, row: usize) -> PerturbedOperator {
        assert!(row < inner.dims().0, "perturbed row out of range");
        PerturbedOperator { inner, row }
    }

    /// Flip the target row's entry iff this block is a partitioned run
    /// (i.e. not the single full-range block the serial path uses).
    fn perturb(&self, block: Block, y_seg: &mut [f64]) {
        let units = self.inner.cost_prefix().len().saturating_sub(1);
        if block.start == 0 && block.end == units {
            return; // full serial block: stay clean
        }
        let r0 = self.inner.rows_through(block.start);
        let r1 = self.inner.rows_through(block.end);
        if (r0..r1).contains(&self.row) {
            let y = &mut y_seg[self.row - r0];
            *y = f64::from_bits(y.to_bits() ^ 1);
        }
    }
}

impl SpmvOperator for PerturbedOperator {
    fn dims(&self) -> (usize, usize) {
        self.inner.dims()
    }

    fn nnz(&self) -> usize {
        self.inner.nnz()
    }

    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        self.inner.cost_prefix()
    }

    fn cost(&self) -> usize {
        self.inner.cost()
    }

    fn rows_through(&self, unit_end: usize) -> usize {
        self.inner.rows_through(unit_end)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        self.inner.run_range(block, x, y_seg)?;
        self.perturb(block, y_seg);
        Ok(())
    }

    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        self.inner.run_range_axpby(block, x, alpha, beta, y_seg)?;
        self.perturb(block, y_seg);
        Ok(())
    }

    fn run_range_multi(&self, block: Block, xs: &DenseMat, ys: &mut DenseMatMut<'_>) -> Result<()> {
        self.inner.run_range_multi(block, xs, ys)?;
        for j in 0..ys.ncols() {
            self.perturb(block, ys.col_mut(j));
        }
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }

    fn format_tag(&self) -> &'static str {
        self.inner.format_tag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample() -> Csr {
        let mut m = banded(150, 3);
        assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(3));
        m
    }

    #[test]
    fn healthy_matrix_is_conformant_across_all_formats() {
        let report = check_matrix(&sample(), &OracleConfig::default()).unwrap();
        assert!(report.is_conformant(), "{report}");
        assert_eq!(report.formats.len() + report.skipped.len(), 5);
        assert!(report.formats.contains(&"csr"));
        assert!(report.formats.contains(&"csr_dtans"));
        assert_eq!(report.strategies, 9); // serial + Fixed(1..=8)
    }

    #[test]
    fn perturbed_operator_is_detected_with_partition_and_row() {
        let m = sample();
        let bad = PerturbedOperator::new(Arc::new(m.clone()), 37);
        let report = check_operator(&bad, &m, &OracleConfig::default()).unwrap();
        assert!(!report.is_conformant());
        // Serial and Fixed(1) runs are clean (no pool, full block), so the
        // first detection is the 2-way partition; every larger partition
        // count re-detects it.
        let first = &report.mismatches[0];
        assert_eq!(first.kind, MismatchKind::ParallelDivergence);
        assert_eq!(first.format, "csr");
        assert_eq!(first.parts, 2);
        assert_eq!(first.row, 37);
        assert_eq!(first.ulps, 1);
        assert_eq!(report.mismatches.len(), 7); // parts 2..=8
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(1.0, f64::from_bits(1.0f64.to_bits() + 1)), 1);
        assert!(ulp_distance(1.0, -1.0) > 1 << 60);
    }

    #[test]
    fn mismatch_display_is_informative() {
        let m = Mismatch {
            kind: MismatchKind::ParallelDivergence,
            format: "sell",
            parts: 4,
            row: 9,
            got: 1.0,
            want: 2.0,
            ulps: 42,
        };
        let s = m.to_string();
        assert!(s.contains("sell") && s.contains("parts=4") && s.contains("row 9"), "{s}");
    }
}
