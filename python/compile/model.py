"""Layer-2 JAX compute graph: the SpMVM entry points that get AOT-lowered
to HLO text for the Rust runtime.

Three entries per size bucket:

* ``spmv_dtans`` — the paper's kernel: fused dtANS decode + SpMVM over a
  CSR-dtANS bundle (calls the Layer-1 Pallas kernel, which lowers inline
  because it is built with ``interpret=True``);
* ``spmv_csr_jnp`` — a scatter-add CSR SpMVM in plain jnp (the cuSPARSE-
  baseline analog on the PJRT path);
* ``dense_matvec`` — dense reference.

All entries compute ``y = A·x + y_in`` (the paper's §III-A semantics).
Shapes are static; the Rust side pads matrices into the bucket it loads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.dtans_decode import spmv_dtans as _pallas_spmv

# Buckets the AOT pipeline compiles. Key -> static shape parameters:
#   nrows (multiple of 32), ncols, nw (stream words), ne (escape slots),
#   nnz (for the CSR entry), max_seg (segment loop bound).
BUCKETS: dict[str, dict[str, int]] = {
    "r64c64": dict(nrows=64, ncols=64, nw=4096, ne=512, nnz=1024, max_seg=32),
    "r256c256": dict(nrows=256, ncols=256, nw=32768, ne=4096, nnz=8192, max_seg=160),
}


def spmv_dtans_entry(bucket: dict[str, int]):
    """Build the fused decode+SpMVM jax function for a bucket. Argument
    order matches ``ref.KernelBundle`` fields, then x, then y_in."""

    def fn(
        dtab,
        vtab,
        d_payload,
        d_isesc,
        v_value,
        v_isesc,
        stream,
        slice_offsets,
        row_nnz,
        d_esc_off,
        v_esc_off,
        d_escapes,
        v_escapes,
        x,
        y_in,
    ):
        y = _pallas_spmv(
            dtab,
            vtab,
            d_payload,
            d_isesc,
            v_value,
            v_isesc,
            stream,
            slice_offsets,
            row_nnz,
            d_esc_off,
            v_esc_off,
            d_escapes,
            v_escapes,
            x,
            max_seg=bucket["max_seg"],
            delta_encode=True,
            interpret=True,
        )
        return (y + y_in,)

    return fn


def spmv_dtans_arg_specs(bucket: dict[str, int]):
    """ShapeDtypeStructs for :func:`spmv_dtans_entry` in argument order."""
    from .kernels.ref import K

    i32 = jnp.int32
    f32 = jnp.float32
    nrows, ncols = bucket["nrows"], bucket["ncols"]
    nslices = nrows // 32
    s = jax.ShapeDtypeStruct
    return [
        s((K,), i32),  # dtab
        s((K,), i32),  # vtab
        s((K,), i32),  # d_payload
        s((K,), i32),  # d_isesc
        s((K,), f32),  # v_value
        s((K,), i32),  # v_isesc
        s((bucket["nw"],), i32),  # stream
        s((nslices + 1,), i32),  # slice_offsets
        s((nrows,), i32),  # row_nnz
        s((nrows,), i32),  # d_esc_off
        s((nrows,), i32),  # v_esc_off
        s((bucket["ne"],), i32),  # d_escapes
        s((bucket["ne"],), f32),  # v_escapes
        s((ncols,), f32),  # x
        s((nrows,), f32),  # y_in
    ]


def spmv_csr_jnp_entry(bucket: dict[str, int]):
    """Scatter-add CSR SpMVM (padded to a fixed nnz; padding rows point at
    row index nrows, column 0, value 0 — a dead scatter target)."""
    nrows = bucket["nrows"]

    def fn(row_ids, cols, vals, x, y_in):
        contrib = vals * jnp.take(x, cols, mode="clip")
        y = jnp.zeros((nrows + 1,), dtype=jnp.float32).at[row_ids].add(contrib)
        return (y[:nrows] + y_in,)

    return fn


def spmv_csr_jnp_arg_specs(bucket: dict[str, int]):
    """ShapeDtypeStructs for :func:`spmv_csr_jnp_entry`."""
    s = jax.ShapeDtypeStruct
    nnz = bucket["nnz"]
    return [
        s((nnz,), jnp.int32),
        s((nnz,), jnp.int32),
        s((nnz,), jnp.float32),
        s((bucket["ncols"],), jnp.float32),
        s((bucket["nrows"],), jnp.float32),
    ]


def dense_matvec_entry(bucket: dict[str, int]):
    """Dense y = A x + y_in."""

    def fn(a, x, y_in):
        return (jnp.dot(a, x) + y_in,)

    return fn


def dense_matvec_arg_specs(bucket: dict[str, int]):
    """ShapeDtypeStructs for :func:`dense_matvec_entry`."""
    s = jax.ShapeDtypeStruct
    return [
        s((bucket["nrows"], bucket["ncols"]), jnp.float32),
        s((bucket["ncols"],), jnp.float32),
        s((bucket["nrows"],), jnp.float32),
    ]


# Entry registry: name -> (fn builder, spec builder).
ENTRIES = {
    "spmv_dtans": (spmv_dtans_entry, spmv_dtans_arg_specs),
    "spmv_csr_jnp": (spmv_csr_jnp_entry, spmv_csr_jnp_arg_specs),
    "dense_matvec": (dense_matvec_entry, dense_matvec_arg_specs),
}
