"""Pure-numpy dtANS reference: encoder, scalar decoder, warp interleaver,
and the SpMVM oracle the Pallas kernel is verified against.

This is a faithful port of the Rust codec (``rust/src/ans/dtans.rs`` and
``rust/src/format/``) restricted to the KERNEL parameter preset
(W=2^16, K=4096, M=256, l=4, o=3, f=2) plus a simplified symbolization
policy (top-frequency dictionary, everything else escapes). The *decoder*
is bit-exact with the Rust one — the Rust CLI can export encoded matrices
that these functions decode (`dtans export-kernel-bundle`); the encoder
here only needs to be self-consistent for the python-side property tests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# ---------------------------------------------------------------------------
# Parameters (KERNEL preset)
# ---------------------------------------------------------------------------

W_BITS = 16
K_BITS = 12
M_BITS = 8
L_SYMS = 4  # symbols per segment (2 nonzeros: delta+value each)
O_WORDS = 3
F_CHECKS = 2
GROUP = L_SYMS // F_CHECKS
W = 1 << W_BITS
K = 1 << K_BITS
M = 1 << M_BITS
WARP = 32


# ---------------------------------------------------------------------------
# Tables
# ---------------------------------------------------------------------------


def normalize_counts(counts: np.ndarray, k: int = K, m_cap: int = M) -> np.ndarray:
    """Normalize positive counts to multiplicities summing to ``k`` with each
    in ``[1, m_cap]`` (greedy cross-entropy repair, as in Rust)."""
    counts = np.asarray(counts, dtype=np.float64)
    n = len(counts)
    assert n >= 1 and n <= k and n * m_cap >= k and (counts > 0).all()
    ideal = counts * k / counts.sum()
    mult = np.clip(np.round(ideal), 1, m_cap).astype(np.int64)
    while mult.sum() != k:
        if mult.sum() > k:
            cost = np.where(mult > 1, counts * np.log2(mult / np.maximum(mult - 1, 1)), np.inf)
            mult[int(np.argmin(cost))] -= 1
        else:
            gain = np.where(mult < m_cap, counts * np.log2((mult + 1) / mult), -np.inf)
            mult[int(np.argmax(gain))] += 1
    return mult.astype(np.uint32)


@dataclass
class Tables:
    """Coding tables for one domain: packed slots + per-symbol inverse."""

    packed: np.ndarray  # uint32[K]: sym<<16 | digit<<8 | (base-1)
    sym_start: np.ndarray  # uint32[nsym]
    sym_mult: np.ndarray  # uint32[nsym]

    @staticmethod
    def build(mult: np.ndarray) -> "Tables":
        mult = np.asarray(mult, dtype=np.uint32)
        assert mult.sum() == K and (mult >= 1).all() and (mult <= M).all()
        packed = np.zeros(K, dtype=np.uint32)
        start = np.zeros(len(mult), dtype=np.uint32)
        pos = 0
        for sym, q in enumerate(mult):
            start[sym] = pos
            q = int(q)
            digits = np.arange(q, dtype=np.uint32)
            packed[pos : pos + q] = (np.uint32(sym) << 16) | (digits << 8) | np.uint32(q - 1)
            pos += q
        return Tables(packed, start, mult)

    @property
    def num_symbols(self) -> int:
        return len(self.sym_mult)

    def base_of(self, sym: int) -> int:
        return int(self.sym_mult[sym])

    def slot_of(self, sym: int, digit: int) -> int:
        assert 0 <= digit < self.sym_mult[sym]
        return int(self.sym_start[sym]) + digit


# ---------------------------------------------------------------------------
# Row codec (scalar)
# ---------------------------------------------------------------------------


def _pack(slots: list[int]) -> list[int]:
    n = 0
    for pos, s in enumerate(slots):
        n |= int(s) << (K_BITS * pos)
    return [(n >> (W_BITS * (O_WORDS - 1 - k))) & (W - 1) for k in range(O_WORDS)]


def _unpack(words: list[int]) -> list[int]:
    n = 0
    for w in words:
        n = (n << W_BITS) | int(w)
    return [(n >> (K_BITS * pos)) & (K - 1) for pos in range(L_SYMS)]


def encode_row(tables: list[Tables], syms: list[int]) -> tuple[list[int], list[bool]]:
    """Two-pass dtANS row encoder. ``syms`` length must be a multiple of l;
    domain of position i is ``i % len(tables)``. Returns (words, branches)."""
    nd = len(tables)
    assert len(syms) % L_SYMS == 0
    nseg = len(syms) // L_SYMS
    if nseg == 0:
        return [], []

    # Base pass: replay r, record branches.
    branches: list[bool] = []
    r = 1
    for t in range(nseg - 1):
        for g in range(F_CHECKS):
            for pos in range(g * GROUP, (g + 1) * GROUP):
                r *= tables[pos % nd].base_of(syms[t * L_SYMS + pos])
            if r >= W:
                branches.append(True)
                r >>= W_BITS
            else:
                branches.append(False)

    # Digit pass (backward).
    d = 0
    rev: list[int] = []
    slots = [tables[pos % nd].slot_of(syms[(nseg - 1) * L_SYMS + pos], 0) for pos in range(L_SYMS)]
    req = _pack(slots)
    for t in range(nseg - 2, -1, -1):
        for k in range(O_WORDS - 1, F_CHECKS - 1, -1):
            rev.append(req[k])
        slots = [0] * L_SYMS
        for g in range(F_CHECKS - 1, -1, -1):
            if branches[t * F_CHECKS + g]:
                d = (d << W_BITS) | req[g]
            else:
                rev.append(req[g])
            for pos in range((g + 1) * GROUP - 1, g * GROUP - 1, -1):
                sym = syms[t * L_SYMS + pos]
                b = tables[pos % nd].base_of(sym)
                slots[pos] = tables[pos % nd].slot_of(sym, d % b)
                d //= b
        req = _pack(slots)
    for k in range(O_WORDS - 1, -1, -1):
        rev.append(req[k])
    assert d == 0, "leftover encoder state must vanish"
    rev.reverse()
    return rev, branches


def decode_row(tables: list[Tables], words: list[int], nsyms: int) -> list[int]:
    """Scalar dtANS row decoder (Algorithm 3)."""
    nd = len(tables)
    assert nsyms % L_SYMS == 0
    nseg = nsyms // L_SYMS
    out: list[int] = []
    if nseg == 0:
        return out
    w = list(int(x) for x in words[:O_WORDS])
    pos = O_WORDS
    d, r = 0, 1
    for t in range(nseg):
        slots = _unpack(w)
        for i, s in enumerate(slots):
            out.append(int(tables[i % nd].packed[s]) >> 16)
        if t + 1 == nseg:
            break
        for g in range(F_CHECKS):
            gd, gr = 0, 1
            for ps in range(g * GROUP, (g + 1) * GROUP):
                e = int(tables[ps % nd].packed[slots[ps]])
                base = (e & 0xFF) + 1
                gd = gd * base + ((e >> 8) & 0xFF)
                gr *= base
            d = d * gr + gd
            r *= gr
            if r >= W:
                w[g] = d & (W - 1)
                d >>= W_BITS
                r >>= W_BITS
            else:
                w[g] = int(words[pos])
                pos += 1
        for k in range(F_CHECKS, O_WORDS):
            w[k] = int(words[pos])
            pos += 1
    assert pos == len(words), f"consumed {pos}/{len(words)} words"
    return out


def interleave_slice(rows: list[tuple[list[int], list[bool], int]]) -> list[int]:
    """Warp-interleave per-row (words, branches, nseg) by load-event order."""
    cursors = [0] * len(rows)
    out: list[int] = []

    def take(lane: int) -> None:
        words, _, _ = rows[lane]
        out.append(words[cursors[lane]])
        cursors[lane] += 1

    for _k in range(O_WORDS):
        for lane, (_, _, nseg) in enumerate(rows):
            if nseg > 0:
                take(lane)
    max_seg = max((nseg for _, _, nseg in rows), default=0)
    for t in range(max(0, max_seg - 1)):
        for g in range(F_CHECKS):
            for lane, (_, branches, nseg) in enumerate(rows):
                if t + 1 < nseg and not branches[t * F_CHECKS + g]:
                    take(lane)
        for _k in range(F_CHECKS, O_WORDS):
            for lane, (_, _, nseg) in enumerate(rows):
                if t + 1 < nseg:
                    take(lane)
    assert all(cursors[i] == len(rows[i][0]) for i in range(len(rows)))
    return out


# ---------------------------------------------------------------------------
# Matrix-level encoding (simplified symbolization) + kernel bundle
# ---------------------------------------------------------------------------


@dataclass
class KernelBundle:
    """Everything the fused decode+SpMVM kernel consumes, padded to a static
    bucket shape. Mirrors the Rust runtime's PJRT inputs."""

    dtab: np.ndarray  # int32[K] packed delta slots
    vtab: np.ndarray  # int32[K] packed value slots
    d_payload: np.ndarray  # int32[K] delta per symbol id (0 for escape)
    d_isesc: np.ndarray  # int32[K]
    v_value: np.ndarray  # float32[K] value per symbol id (0 for escape)
    v_isesc: np.ndarray  # int32[K]
    stream: np.ndarray  # int32[NW]
    slice_offsets: np.ndarray  # int32[NSLICES+1]
    row_nnz: np.ndarray  # int32[NROWS]
    d_esc_off: np.ndarray  # int32[NROWS]
    v_esc_off: np.ndarray  # int32[NROWS]
    d_escapes: np.ndarray  # int32[NE]
    v_escapes: np.ndarray  # float32[NE]
    nrows: int = 0
    ncols: int = 0
    max_seg: int = 0
    delta_encode: bool = True

    def pad_to(self, nrows: int, stream_words: int, escapes: int) -> "KernelBundle":
        """Zero-pad arrays to a static bucket shape (extra rows are empty)."""
        assert nrows % WARP == 0 and nrows >= len(self.row_nnz)
        nslices = nrows // WARP

        def pad(a: np.ndarray, n: int, dt) -> np.ndarray:
            out = np.zeros(n, dtype=dt)
            assert len(a) <= n, f"bucket too small: {len(a)} > {n}"
            out[: len(a)] = a
            return out

        so = pad(self.slice_offsets, nslices + 1, np.int32)
        so[len(self.slice_offsets):] = self.slice_offsets[-1]
        return KernelBundle(
            self.dtab,
            self.vtab,
            self.d_payload,
            self.d_isesc,
            self.v_value,
            self.v_isesc,
            pad(self.stream, stream_words, np.int32),
            so,
            pad(self.row_nnz, nrows, np.int32),
            pad(self.d_esc_off, nrows, np.int32),
            pad(self.v_esc_off, nrows, np.int32),
            pad(self.d_escapes, escapes, np.int32),
            pad(self.v_escapes, escapes, np.float32),
            nrows=nrows,
            ncols=self.ncols,
            max_seg=self.max_seg,
            delta_encode=self.delta_encode,
        )


def _build_domain(counts: dict[int, int], max_keep: int):
    """Keep the most frequent payloads (up to max_keep); rest escape."""
    items = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))[:max_keep]
    payloads = [p for p, _ in items]
    return payloads, {p: i for i, p in enumerate(payloads)}


def encode_matrix(
    rows_cols: list[np.ndarray],
    rows_vals: list[np.ndarray],
    ncols: int,
    delta_encode: bool = True,
    max_dict: int = 1024,
) -> KernelBundle:
    """Encode a CSR-like matrix (per-row column/value arrays) into a
    KernelBundle using the python reference codec."""
    nrows = len(rows_cols)
    rows_deltas = []
    dcounts: dict[int, int] = {}
    vcounts: dict[int, int] = {}
    for cols, vals in zip(rows_cols, rows_vals):
        cols = np.asarray(cols, dtype=np.int64)
        deltas = cols.copy()
        if delta_encode and len(cols) > 1:
            deltas[1:] = cols[1:] - cols[:-1]
        rows_deltas.append(deltas)
        for d in deltas:
            dcounts[int(d)] = dcounts.get(int(d), 0) + 1
        for v in np.asarray(vals, dtype=np.float32):
            b = int(np.float32(v).view(np.uint32))
            vcounts[b] = vcounts.get(b, 0) + 1

    kept_d, dmap = _build_domain(dcounts, max_dict)
    kept_v, vmap = _build_domain(vcounts, max_dict)

    def finalize(kept: list[int], counts: dict[int, int]):
        payloads = list(kept)
        cnts = [max(counts.get(p, 1), 1) for p in payloads]
        isesc = [False] * len(payloads)
        kept_set = set(kept)
        esc_count = sum(c for p, c in counts.items() if p not in kept_set)
        payloads.append(0)
        cnts.append(max(esc_count, 1))
        isesc.append(True)
        # Duplicate hot ids until K slots are fillable under cap M.
        while len(payloads) * M < K:
            hot = int(np.argmax(cnts))
            half = max(cnts[hot] // 2, 1)
            cnts[hot] = max(cnts[hot] - half, 1)
            payloads.append(payloads[hot])
            cnts.append(half)
            isesc.append(isesc[hot])
        mult = normalize_counts(np.array(cnts, dtype=np.float64))
        return payloads, isesc, mult

    d_payloads, d_isesc, d_mult = finalize(kept_d, dcounts)
    v_payloads, v_isesc, v_mult = finalize(kept_v, vcounts)
    dtab = Tables.build(d_mult)
    vtab = Tables.build(v_mult)
    d_pad = int(np.argmax(np.where(np.array(d_isesc), 0, d_mult)))
    v_pad = int(np.argmax(np.where(np.array(v_isesc), 0, v_mult)))
    d_escape_ids = [i for i, e in enumerate(d_isesc) if e]
    v_escape_ids = [i for i, e in enumerate(v_isesc) if e]

    encs = []
    d_escapes: list[int] = []
    v_escapes: list[float] = []
    d_esc_off = [0]
    v_esc_off = [0]
    max_seg = 0
    for cols, vals, deltas in zip(rows_cols, rows_vals, rows_deltas):
        nnz = len(cols)
        nps = L_SYMS // 2
        nseg = -(-nnz // nps) if nnz else 0
        max_seg = max(max_seg, nseg)
        syms: list[int] = []
        for i in range(nseg * nps):
            if i < nnz:
                dlt = int(deltas[i])
                if dlt in dmap:
                    syms.append(dmap[dlt])
                else:
                    syms.append(d_escape_ids[0])
                    d_escapes.append(dlt)
                vb = int(np.float32(vals[i]).view(np.uint32))
                if vb in vmap:
                    syms.append(vmap[vb])
                else:
                    syms.append(v_escape_ids[0])
                    v_escapes.append(float(np.float32(vals[i])))
            else:
                syms.append(d_pad)
                syms.append(v_pad)
        words, branches = encode_row([dtab, vtab], syms)
        encs.append((words, branches, nseg))
        d_esc_off.append(len(d_escapes))
        v_esc_off.append(len(v_escapes))

    nslices = -(-nrows // WARP) if nrows else 0
    stream: list[int] = []
    slice_offsets = [0]
    for s in range(nslices):
        stream.extend(interleave_slice(encs[s * WARP : min((s + 1) * WARP, nrows)]))
        slice_offsets.append(len(stream))

    def per_sym(payloads, isesc):
        out = np.zeros(K, dtype=np.int64)
        esc = np.zeros(K, dtype=np.int32)
        for i, (p, e) in enumerate(zip(payloads, isesc)):
            out[i] = 0 if e else p
            esc[i] = 1 if e else 0
        return out, esc

    d_payload_arr, d_isesc_arr = per_sym(d_payloads, d_isesc)
    v_bits, v_isesc_arr = per_sym(v_payloads, v_isesc)
    v_value_arr = v_bits.astype(np.uint32).view(np.float32)

    return KernelBundle(
        dtab=dtab.packed.view(np.int32).copy(),
        vtab=vtab.packed.view(np.int32).copy(),
        d_payload=d_payload_arr.astype(np.int32),
        d_isesc=d_isesc_arr,
        v_value=v_value_arr,
        v_isesc=v_isesc_arr,
        stream=np.array(stream, dtype=np.int32),
        slice_offsets=np.array(slice_offsets, dtype=np.int32),
        row_nnz=np.array([len(c) for c in rows_cols], dtype=np.int32),
        d_esc_off=np.array(d_esc_off[:-1], dtype=np.int32),
        v_esc_off=np.array(v_esc_off[:-1], dtype=np.int32),
        # Side streams are padded to length >= 1 so gathers are well formed
        # even when nothing escaped.
        d_escapes=np.array(d_escapes or [0], dtype=np.int32),
        v_escapes=np.array(v_escapes or [0.0], dtype=np.float32),
        nrows=nrows,
        ncols=ncols,
        max_seg=max_seg,
        delta_encode=delta_encode,
    )


# ---------------------------------------------------------------------------
# Oracle: scalar decode + SpMVM over a bundle
# ---------------------------------------------------------------------------


def decode_spmv_ref(b: KernelBundle, x: np.ndarray) -> np.ndarray:
    """Scalar replay of the warp-synchronous fused decode+SpMVM: the oracle
    the Pallas kernel must match (float32 accumulation per lane)."""
    nrows = len(b.row_nnz)
    y = np.zeros(nrows, dtype=np.float32)
    nslices = len(b.slice_offsets) - 1
    nps = L_SYMS // 2
    xf = np.asarray(x, dtype=np.float32)
    for s in range(nslices):
        stream = b.stream[b.slice_offsets[s] : b.slice_offsets[s + 1]]
        pos = 0
        lanes = min(WARP, nrows - s * WARP)
        if lanes <= 0:
            continue
        d = [0] * lanes
        r = [1] * lanes
        w = [[0] * O_WORDS for _ in range(lanes)]
        nseg = [-(-int(b.row_nnz[s * WARP + i]) // nps) for i in range(lanes)]
        emitted = [0] * lanes
        col = [0] * lanes
        esc_d = [int(b.d_esc_off[s * WARP + i]) for i in range(lanes)]
        esc_v = [int(b.v_esc_off[s * WARP + i]) for i in range(lanes)]
        acc = [np.float32(0.0) for _ in range(lanes)]
        for k in range(O_WORDS):
            for lane in range(lanes):
                if nseg[lane] > 0:
                    w[lane][k] = int(stream[pos])
                    pos += 1
        slots_l = [[0] * L_SYMS for _ in range(lanes)]
        for t in range(max(nseg, default=0)):
            for lane in range(lanes):
                if t >= nseg[lane]:
                    continue
                slots = _unpack(w[lane])
                slots_l[lane] = slots
                nnz_r = int(b.row_nnz[s * WARP + lane])
                for i in range(nps):
                    if emitted[lane] >= nnz_r:
                        break
                    ds = int(b.dtab[slots[2 * i]]) >> 16
                    vs = int(b.vtab[slots[2 * i + 1]]) >> 16
                    if b.d_isesc[ds]:
                        dlt = int(b.d_escapes[esc_d[lane]])
                        esc_d[lane] += 1
                    else:
                        dlt = int(b.d_payload[ds])
                    if b.v_isesc[vs]:
                        val = np.float32(b.v_escapes[esc_v[lane]])
                        esc_v[lane] += 1
                    else:
                        val = np.float32(b.v_value[vs])
                    c = dlt if (emitted[lane] == 0 or not b.delta_encode) else col[lane] + dlt
                    col[lane] = c
                    emitted[lane] += 1
                    acc[lane] = np.float32(acc[lane] + val * xf[c])
            for g in range(F_CHECKS):
                for lane in range(lanes):
                    if t + 1 >= nseg[lane]:
                        continue
                    gd, gr = 0, 1
                    for ps in range(g * GROUP, (g + 1) * GROUP):
                        tab = b.dtab if ps % 2 == 0 else b.vtab
                        e = int(tab[slots_l[lane][ps]])
                        base = (e & 0xFF) + 1
                        gd = gd * base + ((e >> 8) & 0xFF)
                        gr *= base
                    d[lane] = d[lane] * gr + gd
                    r[lane] *= gr
                    if r[lane] >= W:
                        w[lane][g] = d[lane] & (W - 1)
                        d[lane] >>= W_BITS
                        r[lane] >>= W_BITS
                    else:
                        w[lane][g] = int(stream[pos])
                        pos += 1
            for k in range(F_CHECKS, O_WORDS):
                for lane in range(lanes):
                    if t + 1 >= nseg[lane]:
                        continue
                    w[lane][k] = int(stream[pos])
                    pos += 1
        assert pos == len(stream), f"slice {s}: consumed {pos}/{len(stream)}"
        for lane in range(lanes):
            y[s * WARP + lane] = acc[lane]
    return y


def spmv_csr_ref(rows_cols, rows_vals, x: np.ndarray) -> np.ndarray:
    """Plain float32 CSR SpMVM oracle."""
    y = np.zeros(len(rows_cols), dtype=np.float32)
    xf = np.asarray(x, dtype=np.float32)
    for r, (cols, vals) in enumerate(zip(rows_cols, rows_vals)):
        acc = np.float32(0.0)
        for c, v in zip(np.asarray(cols), np.asarray(vals, dtype=np.float32)):
            acc = np.float32(acc + np.float32(v) * xf[int(c)])
        y[r] = acc
    return y


def random_matrix(rng: np.random.Generator, nrows: int, ncols: int, avg_nnz: float,
                  distinct_vals: int = 16):
    """Random CSR-like matrix for tests: per-row sorted unique columns."""
    rows_cols, rows_vals = [], []
    palette = rng.standard_normal(max(distinct_vals, 1)).astype(np.float32)
    for _ in range(nrows):
        n = min(int(rng.poisson(avg_nnz)), ncols)
        cols = np.sort(rng.choice(ncols, size=n, replace=False)) if n else np.zeros(0, dtype=np.int64)
        vals = palette[rng.integers(0, len(palette), size=n)]
        rows_cols.append(cols.astype(np.int64))
        rows_vals.append(vals)
    return rows_cols, rows_vals
