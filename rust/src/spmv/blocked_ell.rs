//! BlockedEll SpMVM kernels: fixed-lane padded blocks walked with a
//! per-lane stack accumulator (scalar), plus the unrolled wide-accumulator
//! variants under the [`crate::spmv::unrolled`] reassociation policy.
//!
//! Padding carries the [`BlockedEll::PAD_COL`] sentinel and is *skipped*
//! (branch), never gathered — unlike SELL's repeat-a-valid-column padding
//! the sentinel is not a legal index into `x`. Because a row's real
//! elements are exactly positions `j < row_len` in ascending-`j` order,
//! the scalar kernel performs each row's additions in CSR order: a full
//! serial scalar BlockedEll multiply is **bit-identical** to the scalar
//! CSR kernel, and partitioned runs are bit-identical to serial because
//! every row is computed by exactly one block.

use crate::matrix::blocked_ell::BlockedEll;
use crate::spmv::unrolled::{combine_tree, prefetch_x, PREFETCH_AHEAD};
use crate::util::error::Result;

/// `y += A·x` over a BlockedEll matrix (scalar kernel).
///
/// ```
/// use dtans::matrix::{BlockedEll, Coo, Csr};
/// use dtans::spmv::{spmv_blocked_ell, spmv_csr};
/// let mut coo = Coo::new(3, 3);
/// for &(r, c, v) in &[(0, 0, 1.0), (0, 2, 2.0), (2, 1, 3.0)] {
///     coo.push(r, c, v);
/// }
/// let m = Csr::from_coo(&coo);
/// let be = BlockedEll::from_csr(&m, 2, 4);
/// let x = [1.0, 1.0, 1.0];
/// let (mut y, mut want) = (vec![0.0; 3], vec![0.0; 3]);
/// spmv_blocked_ell(&be, &x, &mut y).unwrap();
/// spmv_csr(&m, &x, &mut want).unwrap();
/// assert_eq!(y, want); // bit-identical: same per-row addition order
/// ```
pub fn spmv_blocked_ell(m: &BlockedEll, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    spmv_blocked_ell_window_range(m, 0, m.nwindows(), x, y)
}

/// Scalar kernel over σ-windows `w0..w1`; `y_seg` spans original rows
/// `w0·sigma .. min(w1·sigma, nrows)`. The window-local sort means those
/// windows' positions hold exactly those rows, so the block-local
/// accumulators scatter through `perm` without leaving the segment.
/// Column-major j-outer walk (contiguous memory), one stack accumulator
/// per lane; each row's additions happen in ascending-`j` = CSR order.
pub(crate) fn spmv_blocked_ell_window_range(
    m: &BlockedEll,
    w0: usize,
    w1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    let c = m.block_rows;
    let bpw = m.blocks_per_window();
    let row0 = w0 * m.sigma;
    let b1 = (w1 * bpw).min(m.nblocks());
    for b in (w0 * bpw)..b1 {
        let p0 = b * c;
        let width = m.block_width[b] as usize;
        let base = m.block_ptr[b];
        let mut acc = [0.0f64; BlockedEll::MAX_BLOCK_ROWS];
        for j in 0..width {
            let col_base = base + j * c;
            for t in 0..c {
                let col = m.cols[col_base + t];
                if col != BlockedEll::PAD_COL {
                    acc[t] += m.vals[col_base + t] * x[col as usize];
                }
            }
        }
        for t in 0..c.min(m.nrows - p0) {
            y_seg[m.perm[p0 + t] as usize - row0] += acc[t];
        }
    }
    Ok(())
}

/// Fused scaled update over windows `w0..w1`:
/// `y_seg[i] = alpha·(A·x)[row] + beta·y_seg[i]`. Same per-row
/// accumulation as [`spmv_blocked_ell_window_range`] (each row is owned
/// by exactly one block, so the write-once scaled update is safe), hence
/// bit-identical to the unfused compose.
pub(crate) fn spmv_blocked_ell_window_range_axpby(
    m: &BlockedEll,
    w0: usize,
    w1: usize,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    let c = m.block_rows;
    let bpw = m.blocks_per_window();
    let row0 = w0 * m.sigma;
    let b1 = (w1 * bpw).min(m.nblocks());
    for b in (w0 * bpw)..b1 {
        let p0 = b * c;
        let width = m.block_width[b] as usize;
        let base = m.block_ptr[b];
        let mut acc = [0.0f64; BlockedEll::MAX_BLOCK_ROWS];
        for j in 0..width {
            let col_base = base + j * c;
            for t in 0..c {
                let col = m.cols[col_base + t];
                if col != BlockedEll::PAD_COL {
                    acc[t] += m.vals[col_base + t] * x[col as usize];
                }
            }
        }
        for t in 0..c.min(m.nrows - p0) {
            let i = m.perm[p0 + t] as usize - row0;
            y_seg[i] = alpha * acc[t] + beta * y_seg[i];
        }
    }
    Ok(())
}

/// One lane's (row's) dot product under the unrolled reassociation
/// policy: real elements are exactly positions `j < row_len` in ascending
/// order, so lane assignment `j mod L` matches the policy's within-row
/// position rule; sentinel cells are skipped and perturb neither the
/// lanes nor the fixed combine tree.
#[inline(always)]
fn blocked_ell_row_dot_unrolled<const L: usize>(
    m: &BlockedEll,
    base: usize,
    c: usize,
    t: usize,
    width: usize,
    x: &[f64],
) -> f64 {
    let mut acc = [0.0f64; L];
    let mut j = 0;
    while j + L <= width {
        if j + PREFETCH_AHEAD < width {
            // PAD_COL is usize::MAX-sized: prefetch_x's bounds check
            // turns sentinel prefetches into no-ops.
            prefetch_x(x, m.cols[base + (j + PREFETCH_AHEAD) * c + t] as usize);
        }
        for l in 0..L {
            let idx = base + (j + l) * c + t;
            let col = m.cols[idx];
            if col != BlockedEll::PAD_COL {
                acc[l] += m.vals[idx] * x[col as usize];
            }
        }
        j += L;
    }
    let mut l = 0;
    while j < width {
        let idx = base + j * c + t;
        let col = m.cols[idx];
        if col != BlockedEll::PAD_COL {
            acc[l] += m.vals[idx] * x[col as usize];
        }
        j += 1;
        l += 1;
    }
    combine_tree::<L>(acc)
}

/// Unrolled kernel over windows `w0..w1`; same range contract as
/// [`spmv_blocked_ell_window_range`], each row accumulated under the
/// [`crate::spmv::unrolled`] policy (`L` lanes over the block's padded
/// width, fixed combine tree) — block- and partition-independent.
pub(crate) fn spmv_blocked_ell_window_range_unrolled<const L: usize>(
    m: &BlockedEll,
    w0: usize,
    w1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    let c = m.block_rows;
    let bpw = m.blocks_per_window();
    let row0 = w0 * m.sigma;
    let b1 = (w1 * bpw).min(m.nblocks());
    for b in (w0 * bpw)..b1 {
        let p0 = b * c;
        let width = m.block_width[b] as usize;
        let base = m.block_ptr[b];
        for t in 0..c.min(m.nrows - p0) {
            y_seg[m.perm[p0 + t] as usize - row0] +=
                blocked_ell_row_dot_unrolled::<L>(m, base, c, t, width, x);
        }
    }
    Ok(())
}

/// Fused unrolled kernel — the `_axpby` form of
/// [`spmv_blocked_ell_window_range_unrolled`], same accumulation, scaled
/// update.
pub(crate) fn spmv_blocked_ell_window_range_axpby_unrolled<const L: usize>(
    m: &BlockedEll,
    w0: usize,
    w1: usize,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    let c = m.block_rows;
    let bpw = m.blocks_per_window();
    let row0 = w0 * m.sigma;
    let b1 = (w1 * bpw).min(m.nblocks());
    for b in (w0 * bpw)..b1 {
        let p0 = b * c;
        let width = m.block_width[b] as usize;
        let base = m.block_ptr[b];
        for t in 0..c.min(m.nrows - p0) {
            let acc = blocked_ell_row_dot_unrolled::<L>(m, base, c, t, width, x);
            let i = m.perm[p0 + t] as usize - row0;
            y_seg[i] = alpha * acc + beta * y_seg[i];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::csr::Csr;
    use crate::spmv::csr::spmv_csr;
    use crate::util::propcheck::assert_close;
    use crate::util::rng::Xoshiro256;

    fn sample(n: usize, seed: u64) -> Csr {
        let mut rng = Xoshiro256::seeded(seed);
        let mut m = crate::matrix::gen::structured::powerlaw_rows(n, 5.0, 1.1, &mut rng);
        crate::matrix::gen::assign_values(
            &mut m,
            crate::matrix::gen::ValueDist::Gaussian,
            &mut rng,
        );
        m
    }

    #[test]
    fn scalar_kernel_is_bitwise_csr_various_geometries() {
        // Sentinel-skipped padding + ascending-j per-row order means the
        // scalar BlockedEll kernel performs each row's exact CSR addition
        // sequence — bitwise equality, not just closeness.
        let m = sample(150, 1);
        let mut rng = Xoshiro256::seeded(2);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want = vec![0.0; m.nrows];
        spmv_csr(&m, &x, &mut want).unwrap();
        for (c, sigma) in [(1, 1), (4, 16), (8, 64), (32, 32), (8, 1000)] {
            let be = crate::matrix::blocked_ell::BlockedEll::from_csr(&m, c, sigma);
            let mut y = vec![0.0; m.nrows];
            spmv_blocked_ell(&be, &x, &mut y).unwrap();
            assert_eq!(y, want, "C={c} sigma={sigma}");
        }
    }

    #[test]
    fn window_range_partitions_reassemble_bitwise() {
        let m = sample(130, 3);
        let be = crate::matrix::blocked_ell::BlockedEll::from_csr(&m, 8, 16);
        let mut rng = Xoshiro256::seeded(4);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64()).collect();
        let nw = be.nwindows();
        let mut want = vec![0.0; m.nrows];
        spmv_blocked_ell_window_range(&be, 0, nw, &x, &mut want).unwrap();
        let mut got = vec![0.0; m.nrows];
        let mut got8 = vec![0.0; m.nrows];
        let mut full8 = vec![0.0; m.nrows];
        spmv_blocked_ell_window_range_unrolled::<8>(&be, 0, nw, &x, &mut full8).unwrap();
        for w in [0usize, 2, 5, nw].windows(2) {
            let r0 = w[0] * be.sigma;
            let r1 = (w[1] * be.sigma).min(m.nrows);
            spmv_blocked_ell_window_range(&be, w[0], w[1], &x, &mut got[r0..r1]).unwrap();
            spmv_blocked_ell_window_range_unrolled::<8>(&be, w[0], w[1], &x, &mut got8[r0..r1])
                .unwrap();
        }
        assert_eq!(got, want);
        assert_eq!(got8, full8);
    }

    #[test]
    fn unrolled_is_close_to_scalar_including_short_rows() {
        let m = sample(200, 5);
        let be = crate::matrix::blocked_ell::BlockedEll::from_csr_default(&m);
        let mut rng = Xoshiro256::seeded(6);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want = vec![0.0; m.nrows];
        spmv_csr(&m, &x, &mut want).unwrap();
        let mut got4 = vec![0.0; m.nrows];
        spmv_blocked_ell_window_range_unrolled::<4>(&be, 0, be.nwindows(), &x, &mut got4)
            .unwrap();
        let mut got8 = vec![0.0; m.nrows];
        spmv_blocked_ell_window_range_unrolled::<8>(&be, 0, be.nwindows(), &x, &mut got8)
            .unwrap();
        assert_close(&got4, &want, 1e-12, 1e-15).unwrap();
        assert_close(&got8, &want, 1e-12, 1e-15).unwrap();
    }

    #[test]
    fn axpby_forms_match_unfused_compose_bitwise() {
        let m = sample(90, 7);
        let be = crate::matrix::blocked_ell::BlockedEll::from_csr(&m, 4, 32);
        let mut rng = Xoshiro256::seeded(8);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let y0: Vec<f64> = (0..m.nrows).map(|_| rng.next_f64() * 2.0).collect();
        let nw = be.nwindows();
        for &(alpha, beta) in &[(1.0, 0.0), (-0.5, 1.0), (2.5, -0.75)] {
            let mut tmp = vec![0.0; m.nrows];
            spmv_blocked_ell_window_range(&be, 0, nw, &x, &mut tmp).unwrap();
            let want: Vec<f64> =
                y0.iter().zip(&tmp).map(|(y, t)| alpha * t + beta * y).collect();
            let mut got = y0.clone();
            spmv_blocked_ell_window_range_axpby(&be, 0, nw, &x, alpha, beta, &mut got).unwrap();
            assert_eq!(got, want, "scalar alpha={alpha} beta={beta}");

            let mut tmp4 = vec![0.0; m.nrows];
            spmv_blocked_ell_window_range_unrolled::<4>(&be, 0, nw, &x, &mut tmp4).unwrap();
            let want4: Vec<f64> =
                y0.iter().zip(&tmp4).map(|(y, t)| alpha * t + beta * y).collect();
            let mut got4 = y0.clone();
            spmv_blocked_ell_window_range_axpby_unrolled::<4>(
                &be, 0, nw, &x, alpha, beta, &mut got4,
            )
            .unwrap();
            assert_eq!(got4, want4, "unrolled4 alpha={alpha} beta={beta}");
        }
    }

    #[test]
    fn degenerate_shapes() {
        for (nr, nc) in [(0usize, 0usize), (1, 1), (3, 0), (0, 3)] {
            let m = Csr::new(nr, nc);
            let be = crate::matrix::blocked_ell::BlockedEll::from_csr_default(&m);
            let x = vec![1.0; nc];
            let mut y = vec![0.0; nr];
            spmv_blocked_ell(&be, &x, &mut y).unwrap();
            assert!(y.iter().all(|&v| v == 0.0));
        }
    }
}
