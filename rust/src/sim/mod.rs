//! GPU execution-model simulator: the stand-in for the paper's RTX 5090
//! testbed. Models coalesced transactions, a 96 MB set-associative L2,
//! DRAM/L2 bandwidth roofline, occupancy, and per-kernel instruction
//! costs — enough to reproduce the *shape* of the paper's runtime results
//! (who wins, where the crossover falls, warm vs cold behavior).

pub mod cache;
pub mod device;
pub mod exec;

pub use cache::Cache;
pub use device::GpuModel;
pub use exec::{best_baseline, simulate, KernelKind, SimInput, SimResult};
