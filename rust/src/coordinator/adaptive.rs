//! Online adaptive routing: a latency-learning cost model with bandit
//! exploration (closes ROADMAP item 3 — see `docs/ROUTING.md`).
//!
//! [`RoutePolicy`](super::router::RoutePolicy) chooses a format once, at
//! registration, from static heuristics (size ratio, row-length skew).
//! The paper's Fig. 9 point — *which format wins depends on the matrix*
//! — means that choice can be wrong, and nothing ever corrects it even
//! though [`Metrics`] watches every kernel. The [`AdaptiveRouter`]
//! closes that loop per matrix:
//!
//! * **Arm space.** One [`Arm`] per admissible point on the decision
//!   surface `FormatChoice × KernelVariant × ParHint` ([`ParHint`] maps
//!   onto the engine's [`ParStrategy`](crate::spmv::engine::ParStrategy):
//!   the service's configured strategy, or a forced serial run). Which
//!   formats are admissible is a *residency* question answered by the
//!   store — an artifact-registered matrix with no CSR original cannot
//!   serve CSR-walk formats, and an overlaid (mutated) matrix can only
//!   serve its own composite operator — so the arm list is built from
//!   [`RoutePolicy::admissible_for`](super::router::RoutePolicy::admissible_for)
//!   and violations are the typed
//!   [`DtansError::InadmissibleRoute`](crate::util::error::DtansError).
//! * **Cost model.** A per-arm EWMA over observed kernel latencies,
//!   seeded (best first) from an autotune sweep ([`autotune_seeds`]),
//!   from the GPU-model estimate ([`sim_seeds`]), or not at all — the
//!   static `RoutePolicy` choice then stands until real observations
//!   arrive ([`SeedSource::Static`]).
//! * **Exploration.** Epsilon-greedy: a configurable fraction of
//!   traffic ([`AdaptiveConfig::explore_fraction`]) is served by a
//!   uniformly-random non-incumbent arm; everything else rides the
//!   incumbent. `explored + exploited == routed` always holds
//!   ([`RouteCounters`]). With the fraction at 0 no challenger ever
//!   accumulates observations, so routing is *exactly* the static
//!   choice — the stress driver's bit-identity replay relies on this.
//! * **Hysteresis.** A challenger must beat the incumbent's EWMA by
//!   [`AdaptiveConfig::hysteresis_margin`] for
//!   [`AdaptiveConfig::hysteresis_k`] *consecutive* observations before
//!   the route flips; any interruption resets the streak. Flips are
//!   rare by construction — each one lands in [`RouteFlip`], bumps
//!   [`Metrics::route_flips`] and stamps a standalone
//!   [`Stage::Routed`](crate::obs::Stage) span.
//! * **Override.** [`RouteOverride::Pin`] is the operator escape hatch:
//!   the pinned arm serves all traffic (no exploration, no flips) until
//!   [`RouteOverride::Clear`]. Pinning an inadmissible arm is allowed —
//!   execution then fails with the typed routing error rather than
//!   serving wrong bits.
//!
//! The subsystem is proven stable by the deterministic routing
//! simulator in [`crate::testkit::routing_sim`]: an injected-clock,
//! seeded-latency-oracle harness that replays stationary / drifting /
//! bimodal-noisy regimes through this *real* router and asserts
//! convergence, bounded flap counts and exploration conservation.

use super::metrics::Metrics;
use super::router::FormatChoice;
use crate::format::csr_dtans::CsrDtans;
use crate::matrix::csr::Csr;
use crate::sim::{best_baseline, simulate, GpuModel, KernelKind, SimInput};
use crate::spmv::engine::KernelVariant;
use crate::util::rng::Xoshiro256;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Parallelism half of the arm key. The engine's
/// [`ParStrategy`](crate::spmv::engine::ParStrategy) is a
/// *construction-time* property (it owns the worker pool), so the arm
/// space exposes the two points the service can reach per request
/// without spawning pools: the shared engine's configured strategy, or
/// a forced serial run (pool-free by definition).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParHint {
    /// Execute on the service's shared engine (its configured
    /// `ParStrategy` — `Auto` by default).
    #[default]
    Engine,
    /// Force the calling thread: the serial engine, no partitioning.
    /// Wins on small matrices where fan-out overhead dominates.
    Serial,
}

impl ParHint {
    /// Report label.
    pub fn label(self) -> &'static str {
        match self {
            ParHint::Engine => "engine",
            ParHint::Serial => "serial",
        }
    }
}

/// One point on the routing decision surface:
/// format × kernel variant × parallelism hint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arm {
    /// Executing format.
    pub choice: FormatChoice,
    /// Kernel variant (scalar / unrolled-4 / unrolled-8).
    pub variant: KernelVariant,
    /// Parallelism hint.
    pub par: ParHint,
}

impl Arm {
    /// The default-variant, engine-parallel arm for a format — what a
    /// static [`RoutePolicy`](super::router::RoutePolicy) choice maps to.
    pub fn format(choice: FormatChoice) -> Arm {
        Arm { choice, variant: KernelVariant::default(), par: ParHint::default() }
    }

    /// Compact label, e.g. `csr_dtans/scalar/engine`.
    pub fn label(&self) -> String {
        format!("{}/{}/{}", self.choice.tag(), self.variant.label(), self.par.label())
    }
}

/// Where a matrix's arm estimates came from (the seeding order of
/// `docs/ROUTING.md`: autotune sweep → sim estimate → static heuristic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeedSource {
    /// Offline autotune sweep ([`crate::autotune`]) — most accurate,
    /// paid for with AlphaSparse-scale search cost.
    Autotune,
    /// GPU execution-model estimate ([`crate::sim`]) — cheap, analytic.
    Sim,
    /// No estimate: the static `RoutePolicy` choice stands until real
    /// observations arrive.
    Static,
}

/// One seeded arm estimate.
#[derive(Debug, Clone, Copy)]
pub struct ArmSeed {
    /// The arm being estimated.
    pub arm: Arm,
    /// Estimated per-call latency in microseconds.
    pub est_us: f64,
}

/// Operator escape hatch: pin a matrix's route, or clear the pin.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteOverride {
    /// Serve *all* of this matrix's traffic from one arm — no
    /// exploration, no flips — until cleared. An inadmissible pin is
    /// accepted here and fails at execution with the typed
    /// [`DtansError::InadmissibleRoute`](crate::util::error::DtansError)
    /// (residency is only knowable against the pinned `LoadedMatrix`).
    Pin(Arm),
    /// Return the matrix to learned routing.
    Clear,
}

/// Adaptive-routing knobs. `Default` is **disabled**: the service
/// behaves exactly as static-routing builds did unless a config opts
/// in.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// Master switch. Off ⇒ [`AdaptiveRouter::decide`] returns `None`
    /// and the service never consults the router.
    pub enabled: bool,
    /// Epsilon: fraction of traffic served by a random non-incumbent
    /// arm. `0.0` disables exploration entirely — and with it, flips
    /// (challengers only accumulate observations when explored).
    pub explore_fraction: f64,
    /// EWMA smoothing factor α ∈ (0, 1]: `ewma ← α·obs + (1−α)·ewma`.
    pub ewma_alpha: f64,
    /// Relative margin a challenger must clear: it counts a "win" only
    /// while `challenger_ewma < incumbent_ewma · (1 − margin)`.
    pub hysteresis_margin: f64,
    /// Consecutive wins required before the route flips.
    pub hysteresis_k: u32,
    /// Observations an arm needs before it may challenge at all.
    pub min_observations: u64,
    /// Grow the arm space across all kernel variants (`false`: only the
    /// service's configured variant).
    pub variant_arms: bool,
    /// Add forced-serial ([`ParHint::Serial`]) arms per format.
    pub serial_arms: bool,
    /// Seed for the exploration RNG (deterministic given request order).
    pub seed: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            enabled: false,
            explore_fraction: 0.05,
            ewma_alpha: 0.3,
            hysteresis_margin: 0.10,
            hysteresis_k: 3,
            min_observations: 2,
            variant_arms: false,
            serial_arms: false,
            seed: 0xADA9_7E57,
        }
    }
}

impl AdaptiveConfig {
    /// Enabled, with everything else at defaults.
    pub fn enabled() -> AdaptiveConfig {
        AdaptiveConfig { enabled: true, ..AdaptiveConfig::default() }
    }

    /// Enabled with exploration off: learned state is consulted but
    /// never fed — routing is provably identical to the static policy
    /// (the stress driver's replay oracle runs this config).
    pub fn zero_exploration() -> AdaptiveConfig {
        AdaptiveConfig { enabled: true, explore_fraction: 0.0, ..AdaptiveConfig::default() }
    }
}

/// One routing decision handed to the execution path.
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    /// The arm to execute on.
    pub arm: Arm,
    /// True when this request was an exploration sample.
    pub explored: bool,
    /// True when a [`RouteOverride::Pin`] forced the arm.
    pub pinned: bool,
}

/// One committed route flip (hysteresis-confirmed).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteFlip {
    /// Matrix whose route flipped.
    pub matrix: u64,
    /// Previous incumbent.
    pub from: Arm,
    /// New incumbent.
    pub to: Arm,
    /// Observation count (router-wide) at flip time — the simulator's
    /// injected clock for convergence assertions.
    pub at_observation: u64,
}

/// Conservation counters: `explored + exploited == routed` always.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RouteCounters {
    /// Decisions handed out.
    pub routed: u64,
    /// Decisions that were exploration samples.
    pub explored: u64,
    /// Decisions that rode the incumbent (or a pin).
    pub exploited: u64,
    /// Hysteresis-confirmed route flips.
    pub flips: u64,
}

/// Per-arm EWMA state.
#[derive(Debug, Clone, Copy)]
struct ArmState {
    arm: Arm,
    /// Current latency estimate (µs); seeded or +∞ until observed.
    ewma_us: f64,
    /// Real observations folded in (seeds don't count).
    observations: u64,
}

/// Per-matrix routing state.
#[derive(Debug, Clone)]
struct MatrixState {
    arms: Vec<ArmState>,
    incumbent: Arm,
    pinned: Option<Arm>,
    /// Current challenger and its consecutive-win streak.
    challenger: Option<(Arm, u32)>,
    seed_source: SeedSource,
}

impl MatrixState {
    fn arm_mut(&mut self, arm: Arm) -> Option<&mut ArmState> {
        self.arms.iter_mut().find(|s| s.arm == arm)
    }

    fn ewma_of(&self, arm: Arm) -> Option<f64> {
        self.arms.iter().find(|s| s.arm == arm).map(|s| s.ewma_us)
    }
}

#[derive(Debug, Default)]
struct Inner {
    matrices: std::collections::BTreeMap<u64, MatrixState>,
    rng: Option<Xoshiro256>,
    flips: Vec<RouteFlip>,
    counters: RouteCounters,
    /// Total observations fed in (the flip-trace clock).
    observations: u64,
}

/// The per-matrix online cost model + epsilon-greedy router.
/// Construction is cheap; all state is behind one mutex (arm lists are
/// a handful of entries, decisions are a few comparisons).
pub struct AdaptiveRouter {
    cfg: AdaptiveConfig,
    metrics: Arc<Metrics>,
    inner: Mutex<Inner>,
}

impl AdaptiveRouter {
    /// Build a router. `metrics` receives `route_flips` /
    /// `explore_requests` counters and the standalone `Routed` flip
    /// spans.
    pub fn new(cfg: AdaptiveConfig, metrics: Arc<Metrics>) -> AdaptiveRouter {
        AdaptiveRouter {
            cfg,
            metrics,
            inner: Mutex::new(Inner {
                rng: Some(Xoshiro256::seeded(cfg.seed)),
                ..Default::default()
            }),
        }
    }

    /// The configuration this router runs.
    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    /// Whether the adaptive layer is live at all.
    pub fn is_enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Register a matrix: build its arm list from the admissible
    /// formats (residency-filtered by the caller — see
    /// [`RoutePolicy::admissible_for`](super::router::RoutePolicy::admissible_for)),
    /// fold in any seeded estimates, and install the static choice as
    /// incumbent. Re-registering replaces prior state.
    pub fn register_matrix(
        &self,
        matrix: u64,
        static_choice: FormatChoice,
        admissible: &[FormatChoice],
        base_variant: KernelVariant,
        seeds: &[ArmSeed],
        source: SeedSource,
    ) {
        if !self.cfg.enabled {
            return;
        }
        let variants: Vec<KernelVariant> = if self.cfg.variant_arms {
            KernelVariant::ALL.to_vec()
        } else {
            vec![base_variant]
        };
        let pars: Vec<ParHint> = if self.cfg.serial_arms {
            vec![ParHint::Engine, ParHint::Serial]
        } else {
            vec![ParHint::Engine]
        };
        let mut arms = Vec::new();
        for &choice in admissible {
            for &variant in &variants {
                for &par in &pars {
                    let arm = Arm { choice, variant, par };
                    let seed = seeds.iter().find(|s| s.arm == arm).map(|s| s.est_us);
                    arms.push(ArmState {
                        arm,
                        ewma_us: seed.unwrap_or(f64::INFINITY),
                        observations: 0,
                    });
                }
            }
        }
        let incumbent = Arm { choice: static_choice, variant: base_variant, par: ParHint::Engine };
        if !arms.iter().any(|s| s.arm == incumbent) {
            // The static choice must be servable; a caller that filtered
            // it out still gets a consistent (single-arm) state.
            arms.push(ArmState { arm: incumbent, ewma_us: f64::INFINITY, observations: 0 });
        }
        self.inner.lock().unwrap().matrices.insert(
            matrix,
            MatrixState { arms, incumbent, pinned: None, challenger: None, seed_source: source },
        );
    }

    /// Remove a matrix from adaptation. The service calls this on
    /// `append`: an overlaid matrix's composite operator is the only
    /// correct execution surface (its base encoding is stale), so the
    /// registered route must stand until a future re-registration.
    pub fn retire(&self, matrix: u64) {
        self.inner.lock().unwrap().matrices.remove(&matrix);
    }

    /// Apply or clear an operator pin.
    pub fn set_override(&self, matrix: u64, ov: RouteOverride) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(st) = inner.matrices.get_mut(&matrix) {
            st.pinned = match ov {
                RouteOverride::Pin(arm) => Some(arm),
                RouteOverride::Clear => None,
            };
            st.challenger = None;
        }
    }

    /// Route one request. `None` when disabled or the matrix is
    /// unregistered/retired — the caller then executes the registered
    /// operator exactly as static-routing builds did.
    pub fn decide(&self, matrix: u64) -> Option<RouteDecision> {
        if !self.cfg.enabled {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let mut rng = inner.rng.take().expect("router rng");
        let decision = match inner.matrices.get(&matrix) {
            None => None,
            Some(st) => {
                if let Some(arm) = st.pinned {
                    Some(RouteDecision { arm, explored: false, pinned: true })
                } else if st.arms.len() > 1 && rng.chance(self.cfg.explore_fraction) {
                    let others: Vec<Arm> = st
                        .arms
                        .iter()
                        .map(|s| s.arm)
                        .filter(|a| *a != st.incumbent)
                        .collect();
                    let arm = others[rng.below_usize(others.len())];
                    Some(RouteDecision { arm, explored: true, pinned: false })
                } else {
                    Some(RouteDecision { arm: st.incumbent, explored: false, pinned: false })
                }
            }
        };
        inner.rng = Some(rng);
        if let Some(d) = &decision {
            inner.counters.routed += 1;
            if d.explored {
                inner.counters.explored += 1;
                self.metrics.explore_requests.fetch_add(1, Ordering::Relaxed);
            } else {
                inner.counters.exploited += 1;
            }
            self.metrics.routed_requests.fetch_add(1, Ordering::Relaxed);
        }
        decision
    }

    /// Feed one observed kernel latency back into the cost model, then
    /// run the hysteresis check. Observations for retired/unknown
    /// matrices or arms are dropped silently (a request may complete
    /// after its matrix was retired by an append).
    pub fn observe(&self, matrix: u64, arm: Arm, latency_us: f64) {
        if !self.cfg.enabled || !latency_us.is_finite() || latency_us < 0.0 {
            return;
        }
        let alpha = self.cfg.ewma_alpha.clamp(0.0, 1.0);
        let mut inner = self.inner.lock().unwrap();
        inner.observations += 1;
        let now = inner.observations;
        let Some(st) = inner.matrices.get_mut(&matrix) else { return };
        let Some(s) = st.arm_mut(arm) else { return };
        s.ewma_us = if s.observations == 0 || !s.ewma_us.is_finite() {
            latency_us
        } else {
            alpha * latency_us + (1.0 - alpha) * s.ewma_us
        };
        s.observations += 1;

        if st.pinned.is_some() {
            return; // pinned routes never flip
        }
        // Hysteresis: the best sufficiently-observed arm must beat the
        // incumbent by the margin for K consecutive observations.
        let incumbent_ewma = st.ewma_of(st.incumbent).unwrap_or(f64::INFINITY);
        let bar = incumbent_ewma * (1.0 - self.cfg.hysteresis_margin);
        let best = st
            .arms
            .iter()
            .filter(|s| s.arm != st.incumbent && s.observations >= self.cfg.min_observations)
            .filter(|s| s.ewma_us < bar)
            .min_by(|a, b| a.ewma_us.total_cmp(&b.ewma_us))
            .map(|s| s.arm);
        match best {
            None => st.challenger = None,
            Some(challenger) => {
                let wins = match st.challenger {
                    Some((c, w)) if c == challenger => w + 1,
                    _ => 1,
                };
                if wins >= self.cfg.hysteresis_k {
                    let from = st.incumbent;
                    st.incumbent = challenger;
                    st.challenger = None;
                    inner.flips.push(RouteFlip {
                        matrix,
                        from,
                        to: challenger,
                        at_observation: now,
                    });
                    inner.counters.flips += 1;
                    self.metrics.record_route_flip(
                        matrix,
                        from.choice.tag(),
                        challenger.choice.tag(),
                        "hysteresis",
                    );
                } else {
                    st.challenger = Some((challenger, wins));
                }
            }
        }
    }

    /// Current incumbent arm of a matrix.
    pub fn incumbent(&self, matrix: u64) -> Option<Arm> {
        self.inner.lock().unwrap().matrices.get(&matrix).map(|s| s.incumbent)
    }

    /// Current EWMA estimate (µs) for one arm of a matrix.
    pub fn estimate_us(&self, matrix: u64, arm: Arm) -> Option<f64> {
        self.inner.lock().unwrap().matrices.get(&matrix).and_then(|s| s.ewma_of(arm))
    }

    /// Where this matrix's estimates were seeded from.
    pub fn seed_source(&self, matrix: u64) -> Option<SeedSource> {
        self.inner.lock().unwrap().matrices.get(&matrix).map(|s| s.seed_source)
    }

    /// The admissible arms of a matrix (empty when unregistered).
    pub fn admissible_arms(&self, matrix: u64) -> Vec<Arm> {
        self.inner
            .lock()
            .unwrap()
            .matrices
            .get(&matrix)
            .map(|s| s.arms.iter().map(|a| a.arm).collect())
            .unwrap_or_default()
    }

    /// Union of admissible format tags across every registered matrix —
    /// the stress driver's routing-conservation oracle checks executed
    /// tags against this set.
    pub fn admissible_tag_union(&self) -> Vec<&'static str> {
        let inner = self.inner.lock().unwrap();
        let mut tags: Vec<&'static str> = inner
            .matrices
            .values()
            .flat_map(|s| s.arms.iter().map(|a| a.arm.choice.tag()))
            .collect();
        tags.sort_unstable();
        tags.dedup();
        tags
    }

    /// The committed flip trace, in order.
    pub fn flips(&self) -> Vec<RouteFlip> {
        self.inner.lock().unwrap().flips.clone()
    }

    /// Conservation counters (`explored + exploited == routed`).
    pub fn counters(&self) -> RouteCounters {
        self.inner.lock().unwrap().counters
    }
}

/// Seed arm estimates from the GPU execution-model simulator: the
/// CSR-walk formats get the best baseline kernel's time, CSR-dtANS the
/// fused decode kernel's. Cheap (analytic model, no kernel runs) —
/// the middle rung of the seeding ladder.
pub fn sim_seeds(csr: &Csr, enc: &CsrDtans, admissible: &[FormatChoice]) -> Vec<ArmSeed> {
    let dev = GpuModel::RTX5090;
    let inp = SimInput { csr, sell: None, enc: Some(enc), precision: enc.precision };
    let (_, base) = best_baseline(&inp, &dev, true);
    let dtans = simulate(KernelKind::CsrDtans, &inp, &dev, true);
    admissible
        .iter()
        .map(|&choice| ArmSeed {
            arm: Arm::format(choice),
            est_us: match choice {
                FormatChoice::CsrDtans => dtans.time_us,
                FormatChoice::Csr | FormatChoice::BlockedEll => base.time_us,
            },
        })
        .collect()
}

/// Seed arm estimates from an offline autotune sweep (the top rung):
/// each evaluated candidate maps onto the admissible format it would
/// execute as, keeping the fastest estimate per format.
pub fn autotune_seeds(
    tune: &crate::autotune::TuneResult,
    admissible: &[FormatChoice],
) -> Vec<ArmSeed> {
    let mut seeds: Vec<ArmSeed> = Vec::new();
    for (cand, us) in &tune.evaluated {
        let choice = match cand.kind {
            KernelKind::CsrScalar | KernelKind::CsrVector | KernelKind::Coo => FormatChoice::Csr,
            // SELL's balanced slices are this repo's BlockedELL stand-in.
            KernelKind::Sell => FormatChoice::BlockedEll,
            KernelKind::CsrDtans => FormatChoice::CsrDtans,
        };
        if !admissible.contains(&choice) {
            continue;
        }
        let arm = Arm::format(choice);
        match seeds.iter_mut().find(|s| s.arm == arm) {
            Some(s) => s.est_us = s.est_us.min(*us),
            None => seeds.push(ArmSeed { arm, est_us: *us }),
        }
    }
    seeds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsConfig;

    fn router(cfg: AdaptiveConfig) -> AdaptiveRouter {
        AdaptiveRouter::new(cfg, Arc::new(Metrics::with_obs(ObsConfig::default())))
    }

    fn two_arm_router(cfg: AdaptiveConfig) -> (AdaptiveRouter, Arm, Arm) {
        let r = router(cfg);
        r.register_matrix(
            1,
            FormatChoice::CsrDtans,
            &[FormatChoice::CsrDtans, FormatChoice::Csr],
            KernelVariant::default(),
            &[],
            SeedSource::Static,
        );
        (r, Arm::format(FormatChoice::CsrDtans), Arm::format(FormatChoice::Csr))
    }

    #[test]
    fn disabled_router_decides_nothing() {
        let r = router(AdaptiveConfig::default());
        r.register_matrix(
            1,
            FormatChoice::Csr,
            &[FormatChoice::Csr],
            KernelVariant::default(),
            &[],
            SeedSource::Static,
        );
        assert!(r.decide(1).is_none());
        assert_eq!(r.counters().routed, 0);
    }

    #[test]
    fn zero_exploration_is_exactly_the_static_choice() {
        let (r, dtans, csr) = two_arm_router(AdaptiveConfig::zero_exploration());
        for _ in 0..200 {
            let d = r.decide(1).unwrap();
            assert_eq!(d.arm, dtans);
            assert!(!d.explored);
            // Only the incumbent is ever observed — the challenger can
            // never accumulate the observations hysteresis demands.
            r.observe(1, d.arm, 500.0);
        }
        let c = r.counters();
        assert_eq!((c.routed, c.explored, c.exploited, c.flips), (200, 0, 200, 0));
        assert!(r.flips().is_empty());
        assert_eq!(r.incumbent(1), Some(dtans));
        // Even with a (stale, seeded-nowhere) fast estimate on the
        // challenger, zero real observations means zero flips.
        assert_eq!(r.estimate_us(1, csr), Some(f64::INFINITY));
    }

    #[test]
    fn hysteresis_requires_k_consecutive_margin_wins() {
        let cfg = AdaptiveConfig {
            explore_fraction: 0.0, // drive observations by hand
            hysteresis_k: 3,
            hysteresis_margin: 0.10,
            min_observations: 2,
            ..AdaptiveConfig::enabled()
        };
        let (r, dtans, csr) = two_arm_router(cfg);
        r.observe(1, dtans, 1000.0);
        r.observe(1, dtans, 1000.0);
        // Challenger at 8% better: inside the 10% margin, never flips.
        for _ in 0..20 {
            r.observe(1, csr, 920.0);
        }
        assert_eq!(r.incumbent(1), Some(dtans));
        assert!(r.flips().is_empty());
        // 40% better: needs exactly K observations past min_observations.
        r.observe(1, csr, 600.0); // obs pulls EWMA down; win streak 1
        r.observe(1, csr, 600.0); // streak 2
        assert_eq!(r.incumbent(1), Some(dtans));
        r.observe(1, csr, 600.0); // streak 3 == K: flip
        assert_eq!(r.incumbent(1), Some(csr));
        let flips = r.flips();
        assert_eq!(flips.len(), 1);
        assert_eq!((flips[0].matrix, flips[0].from, flips[0].to), (1, dtans, csr));
        assert_eq!(r.counters().flips, 1);
    }

    #[test]
    fn interrupted_streaks_reset() {
        let cfg = AdaptiveConfig {
            explore_fraction: 0.0,
            hysteresis_k: 3,
            hysteresis_margin: 0.10,
            min_observations: 1,
            ewma_alpha: 1.0, // each observation replaces the estimate
            ..AdaptiveConfig::enabled()
        };
        let (r, dtans, csr) = two_arm_router(cfg);
        r.observe(1, dtans, 1000.0);
        r.observe(1, csr, 500.0); // streak 1
        r.observe(1, csr, 500.0); // streak 2
        r.observe(1, csr, 990.0); // inside margin: streak resets
        r.observe(1, csr, 500.0); // streak 1
        r.observe(1, csr, 500.0); // streak 2
        assert_eq!(r.incumbent(1), Some(dtans));
        r.observe(1, csr, 500.0); // streak 3: flip
        assert_eq!(r.incumbent(1), Some(csr));
        assert_eq!(r.flips().len(), 1);
    }

    #[test]
    fn exploration_conservation_holds() {
        let cfg = AdaptiveConfig { explore_fraction: 0.5, ..AdaptiveConfig::enabled() };
        let (r, _, _) = two_arm_router(cfg);
        for _ in 0..500 {
            let d = r.decide(1).unwrap();
            r.observe(1, d.arm, 100.0);
        }
        let c = r.counters();
        assert_eq!(c.routed, 500);
        assert_eq!(c.explored + c.exploited, c.routed);
        // ε = 0.5 over 500 draws: both branches must actually occur.
        assert!(c.explored > 50 && c.exploited > 50, "{c:?}");
    }

    #[test]
    fn pinned_routes_never_explore_or_flip() {
        let cfg = AdaptiveConfig {
            explore_fraction: 1.0, // would explore every request
            min_observations: 1,
            hysteresis_k: 1,
            ..AdaptiveConfig::enabled()
        };
        let (r, dtans, csr) = two_arm_router(cfg);
        r.set_override(1, RouteOverride::Pin(csr));
        for _ in 0..50 {
            let d = r.decide(1).unwrap();
            assert!(d.pinned && !d.explored);
            assert_eq!(d.arm, csr);
            r.observe(1, csr, 10.0);
            r.observe(1, dtans, 10_000.0);
        }
        assert!(r.flips().is_empty(), "pinned matrices must not flip");
        r.set_override(1, RouteOverride::Clear);
        assert!(r.decide(1).unwrap().explored || r.decide(1).unwrap().explored);
    }

    #[test]
    fn seeds_order_arms_before_any_observation() {
        let (r, dtans, csr) = two_arm_router(AdaptiveConfig::zero_exploration());
        // Re-register with sim-style seeds: estimates land in the EWMA.
        r.register_matrix(
            1,
            FormatChoice::CsrDtans,
            &[FormatChoice::CsrDtans, FormatChoice::Csr],
            KernelVariant::default(),
            &[ArmSeed { arm: dtans, est_us: 80.0 }, ArmSeed { arm: csr, est_us: 120.0 }],
            SeedSource::Sim,
        );
        assert_eq!(r.estimate_us(1, dtans), Some(80.0));
        assert_eq!(r.estimate_us(1, csr), Some(120.0));
        assert_eq!(r.seed_source(1), Some(SeedSource::Sim));
        // A seed is advisory: the first real observation replaces it.
        r.observe(1, dtans, 10.0);
        assert_eq!(r.estimate_us(1, dtans), Some(10.0));
    }

    #[test]
    fn sim_seeds_cover_admissible_formats() {
        use crate::format::csr_dtans::EncodeOptions;
        use crate::matrix::gen::structured::banded;
        let m = banded(2000, 2);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let adm = [FormatChoice::Csr, FormatChoice::CsrDtans];
        let seeds = sim_seeds(&m, &enc, &adm);
        assert_eq!(seeds.len(), 2);
        assert!(seeds.iter().all(|s| s.est_us > 0.0 && s.est_us.is_finite()));
    }

    #[test]
    fn retire_removes_state_and_decide_returns_none() {
        let (r, _, _) = two_arm_router(AdaptiveConfig::enabled());
        assert!(r.decide(1).is_some());
        r.retire(1);
        assert!(r.decide(1).is_none());
        assert!(r.admissible_arms(1).is_empty());
        // Late observations for a retired matrix are dropped silently.
        r.observe(1, Arm::format(FormatChoice::Csr), 1.0);
    }

    #[test]
    fn variant_and_serial_dimensions_expand_the_arm_space() {
        let cfg =
            AdaptiveConfig { variant_arms: true, serial_arms: true, ..AdaptiveConfig::enabled() };
        let r = router(cfg);
        r.register_matrix(
            7,
            FormatChoice::Csr,
            &[FormatChoice::Csr, FormatChoice::CsrDtans],
            KernelVariant::default(),
            &[],
            SeedSource::Static,
        );
        // 2 formats × 3 variants × 2 par hints.
        assert_eq!(r.admissible_arms(7).len(), 12);
        assert_eq!(r.admissible_tag_union(), vec!["csr", "csr_dtans"]);
    }
}
