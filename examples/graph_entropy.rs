//! The Fig. 4 experiment as a standalone example: entropy reduction from
//! delta-encoding column indices on the three random-graph models, at the
//! paper's average degrees (5, 10, 20), for growing node counts.
//!
//! Run: `cargo run --release --example graph_entropy`

use dtans::matrix::gen::{gen_graph_csr, GraphModel};
use dtans::matrix::stats::MatrixStats;
use dtans::util::rng::Xoshiro256;

fn main() {
    println!(
        "{:<16} {:>6} {:>9} {:>12} {:>12} {:>8}",
        "model", "degree", "nodes", "H(indices)", "H(deltas)", "ratio"
    );
    for model in [
        GraphModel::ErdosRenyi,
        GraphModel::WattsStrogatz,
        GraphModel::BarabasiAlbert,
    ] {
        for degree in [5.0, 10.0, 20.0] {
            let mut n = 1 << 10;
            while n <= 1 << 16 {
                // Median of three seeds, as in the paper.
                let mut ratios: Vec<(f64, f64, f64)> = (0..3)
                    .map(|s| {
                        let mut rng = Xoshiro256::seeded(100 + s);
                        let m = gen_graph_csr(model, n, degree, &mut rng);
                        let st = MatrixStats::compute(&m);
                        (st.h_indices, st.h_deltas, st.relative_delta_entropy())
                    })
                    .collect();
                ratios.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
                let (hi, hd, ratio) = ratios[1];
                println!(
                    "{:<16} {:>6} {:>9} {:>12.3} {:>12.3} {:>8.3}",
                    model.label(),
                    degree,
                    n,
                    hi,
                    hd,
                    ratio
                );
                n <<= 2;
            }
        }
    }
    println!("\nratio < 1 everywhere: delta-encoding reduces index entropy on all three models,");
    println!("reproducing the paper's Fig. 4.");
}
