//! Tiny CSV + markdown table writers for experiment reports.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// An in-memory table with a header row; serializes to CSV or markdown.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Column names.
    pub header: Vec<String>,
    /// Row-major cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column names.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header length).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Render as CSV (cells containing commas/quotes are quoted).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(s, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        s
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(s, "|{}|", vec!["---"; self.header.len()].join("|"));
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// Write CSV to a path, creating parent directories.
    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Format an f64 with `digits` significant decimals, trimming noise.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into(), "x,y".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn markdown_has_separator() {
        let mut t = Table::new(&["a"]);
        t.push(vec!["v".into()]);
        let md = t.to_markdown();
        assert!(md.contains("|---|"));
        assert!(md.contains("| v |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["only-one".into()]);
    }
}
