//! Compressed sparse row (CSR) matrix — the paper's starting format.

use super::coo::Coo;
use crate::util::error::{DtansError, Result};

/// CSR matrix: values and column indices in row-major order plus per-row
/// start offsets (Fig. 2 of the paper).
///
/// Column indices within each row are kept strictly ascending (the paper
/// sorts nonzeros by column before delta-encoding); [`Csr::from_coo`]
/// guarantees this.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Csr {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row start offsets, length `nrows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column index per nonzero, strictly ascending within a row.
    pub cols: Vec<u32>,
    /// Value per nonzero.
    pub vals: Vec<f64>,
}

impl Csr {
    /// Empty matrix of given shape.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Csr {
            nrows,
            ncols,
            row_ptr: vec![0; nrows + 1],
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Number of nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Average number of nonzeros per row (the paper's `annzpr`).
    pub fn annzpr(&self) -> f64 {
        if self.nrows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.nrows as f64
        }
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_cols(&self, r: usize) -> &[u32] {
        &self.cols[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_vals(&self, r: usize) -> &[f64] {
        &self.vals[self.row_ptr[r]..self.row_ptr[r + 1]]
    }

    /// Number of nonzeros in row `r`.
    #[inline]
    pub fn row_len(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Longest row.
    pub fn max_row_len(&self) -> usize {
        (0..self.nrows).map(|r| self.row_len(r)).max().unwrap_or(0)
    }

    /// Build from COO (sorts and sums duplicates).
    pub fn from_coo(coo: &Coo) -> Csr {
        let s = coo.sorted_dedup();
        let mut m = Csr::new(s.nrows, s.ncols);
        m.cols = s.cols;
        m.vals = s.vals;
        let mut ptr = vec![0usize; s.nrows + 1];
        for &r in &s.rows {
            ptr[r as usize + 1] += 1;
        }
        for i in 0..s.nrows {
            ptr[i + 1] += ptr[i];
        }
        m.row_ptr = ptr;
        m
    }

    /// Convert back to COO (row-major order).
    pub fn to_coo(&self) -> Coo {
        let mut out = Coo::new(self.nrows, self.ncols);
        for r in 0..self.nrows {
            for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                out.push(r as u32, self.cols[i], self.vals[i]);
            }
        }
        out
    }

    /// Validate structural invariants (monotone `row_ptr`, strictly
    /// ascending in-row columns, in-range indices).
    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.nrows + 1 {
            return Err(DtansError::InvalidMatrix("row_ptr length".into()));
        }
        if *self.row_ptr.last().unwrap_or(&0) != self.nnz() || self.cols.len() != self.vals.len() {
            return Err(DtansError::InvalidMatrix("array lengths disagree".into()));
        }
        for r in 0..self.nrows {
            if self.row_ptr[r] > self.row_ptr[r + 1] {
                return Err(DtansError::InvalidMatrix(format!("row_ptr not monotone at {r}")));
            }
            let cols = self.row_cols(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return Err(DtansError::InvalidMatrix(format!(
                        "columns not strictly ascending in row {r}"
                    )));
                }
            }
            if let Some(&last) = cols.last() {
                if last as usize >= self.ncols {
                    return Err(DtansError::InvalidMatrix(format!("column out of range in row {r}")));
                }
            }
        }
        Ok(())
    }

    /// Dense row-major materialization (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for r in 0..self.nrows {
            for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                d[r * self.ncols + self.cols[i] as usize] = self.vals[i];
            }
        }
        d
    }

    /// In-memory CSR byte size with 32-bit indices and f64 values
    /// (convenience for the quickstart; see [`super::SizeModel`] for the
    /// precision-parametric accounting).
    pub fn size_bytes_f64(&self) -> usize {
        self.nnz() * 12 + (self.nrows + 1) * 4
    }

    /// Is the sparsity pattern + values symmetric? (Used by the Fig. 9
    /// experiment which mimics AlphaSparse's triangular handling.)
    pub fn is_symmetric(&self) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        // Build transpose lookup and compare.
        let t = Csr::from_coo(&{
            let mut c = Coo::new(self.ncols, self.nrows);
            for r in 0..self.nrows {
                for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                    c.push(self.cols[i], r as u32, self.vals[i]);
                }
            }
            c
        });
        t.row_ptr == self.row_ptr && t.cols == self.cols && t.vals == self.vals
    }

    /// Lower-triangular part (including diagonal) — AlphaSparse's storage
    /// for symmetric matrices.
    pub fn lower_triangular(&self) -> Csr {
        let mut c = Coo::new(self.nrows, self.ncols);
        for r in 0..self.nrows {
            for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                if self.cols[i] as usize <= r {
                    c.push(r as u32, self.cols[i], self.vals[i]);
                }
            }
        }
        Csr::from_coo(&c)
    }

    /// Round all values to f32 and back (the 32-bit precision setting).
    pub fn round_to_f32(&self) -> Csr {
        let mut m = self.clone();
        for v in &mut m.vals {
            *v = *v as f32 as f64;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Csr {
        // Fig. 2 of the paper.
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[(0, 1, 7.0), (0, 3, 5.0), (1, 0, 3.0), (1, 2, 2.0), (2, 1, 4.0), (3, 3, 1.0)] {
            coo.push(r, c, v);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn matches_paper_fig2() {
        let m = example();
        assert_eq!(m.vals, vec![7.0, 5.0, 3.0, 2.0, 4.0, 1.0]);
        assert_eq!(m.cols, vec![1, 3, 0, 2, 1, 3]);
        assert_eq!(m.row_ptr, vec![0, 2, 4, 5, 6]);
        m.validate().unwrap();
    }

    #[test]
    fn coo_roundtrip() {
        let m = example();
        let back = Csr::from_coo(&m.to_coo());
        assert_eq!(m, back);
    }

    #[test]
    fn dense_matches() {
        let m = example();
        let d = m.to_dense();
        assert_eq!(d[0 * 4 + 1], 7.0);
        assert_eq!(d[3 * 4 + 3], 1.0);
        assert_eq!(d.iter().filter(|&&x| x != 0.0).count(), 6);
    }

    #[test]
    fn symmetric_detection() {
        let mut coo = Coo::new(3, 3);
        for &(r, c) in &[(0, 1), (1, 0), (1, 2), (2, 1), (0, 0)] {
            coo.push(r, c, 1.0);
        }
        let m = Csr::from_coo(&coo);
        assert!(m.is_symmetric());
        let lt = m.lower_triangular();
        assert_eq!(lt.nnz(), 3); // (0,0),(1,0),(2,1)
        assert!(!example().is_symmetric());
    }

    #[test]
    fn annzpr_and_maxrow() {
        let m = example();
        assert!((m.annzpr() - 1.5).abs() < 1e-12);
        assert_eq!(m.max_row_len(), 2);
    }
}
