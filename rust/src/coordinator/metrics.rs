//! Service metrics: request counters, store counters, solver counters,
//! and latency quantiles over log-bucketed histograms
//! ([`crate::obs::hist::LogHistogram`]) — aggregate and broken out per
//! kernel format
//! ([`SpmvOperator::format_tag`](crate::spmv::operator::SpmvOperator::format_tag)),
//! so dtANS vs CSR routing is observable in production.
//!
//! Through PR 6 the quantiles came from 64k sliding sample rings; those
//! windowed the data (quantiles forgot everything older than the last 64k
//! samples) and cost 512 KiB per reservoir. The histograms keep **every**
//! sample — exact `count`/`max`, ≤0.78% relative quantile error — in
//! ~30 KiB of constant memory each, and merge without resorting.
//!
//! `Metrics` also owns the request-flow [`Tracer`]: the store, dispatcher
//! and pool workers all share `Arc<Metrics>` already, so embedding the
//! collector here threads tracing through the whole pipeline without a
//! new shared handle. Export surfaces live in [`crate::obs::export`]
//! (Prometheus text + JSON snapshot); the stage/label contract is in
//! `docs/OBSERVABILITY.md`.
//!
//! A whole iterative solve ([`crate::coordinator::service::SpmvService::solve`])
//! is **one** request-level sample: [`Metrics::record_solve`] pushes a
//! single end-to-end latency into the aggregate and per-format
//! histograms, and its iteration count into a separate iterations
//! histogram. Recording each of a solve's N inner multiplies as its own
//! latency sample would flood the format histograms with N correlated
//! sub-millisecond entries and drag p99 toward the solver's inner-loop
//! time — the skew called out in the per-format breakdown work.

use crate::obs::hist::LogHistogram;
use crate::obs::span::Stage;
use crate::obs::trace::{ObsConfig, Tracer};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Lock-free counters + mutexed histograms + the span tracer.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Requests shed at admission (queue full, tenant quota, or closed
    /// queue) — they were `submitted` but never queued, so the
    /// conservation identity is
    /// `completed + failed + shed + expired == submitted`.
    pub shed: AtomicU64,
    /// Subset of `shed`: rejections from a per-tenant token-bucket
    /// quota.
    pub quota_rejected: AtomicU64,
    /// Requests whose deadline elapsed before execution; rejected at
    /// dispatch with `DeadlineExceeded`, never run.
    pub expired: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Multi-request same-matrix batches that took the coalesced SpMM
    /// fast path (one `run_multi` engine call for the whole batch).
    pub coalesced_batches: AtomicU64,
    /// Requests served through those coalesced batches
    /// (`coalesced_requests / coalesced_batches` = mean amortization
    /// factor).
    pub coalesced_requests: AtomicU64,
    /// Gauge: admission-queue depth after the most recent submit or
    /// dispatch (both sides update it — see
    /// [`AdmissionQueue::take_batch_depth`](crate::coordinator::admission::AdmissionQueue::take_batch_depth)).
    pub queue_depth: AtomicU64,
    /// High-water mark of the admission queue over the service's life.
    pub queue_depth_peak: AtomicU64,
    /// Registrations served from the on-disk artifact cache (encode
    /// skipped).
    pub store_hits: AtomicU64,
    /// Registrations that had to encode.
    pub store_misses: AtomicU64,
    /// Matrices evicted from residency by the byte budget.
    pub evictions: AtomicU64,
    /// Background artifact persists that failed (the matrix stays
    /// resident and unevictable — the budget cannot be enforced for it).
    pub persist_failures: AtomicU64,
    /// Cold loads (evicted matrices faulted back in from disk).
    pub cold_loads: AtomicU64,
    /// Successful store pin acquisitions
    /// ([`crate::store::MatrixStore::acquire`]) — a solve must cost
    /// exactly one of these no matter how many iterations it runs.
    pub acquires: AtomicU64,
    /// Individual COO update entries appended to mutable matrices
    /// ([`crate::store::MatrixStore::append`]).
    pub deltas_appended: AtomicU64,
    /// Gauge: total entries currently held in RAM-only delta overlays
    /// across all registered matrices (recomputed under the store lock at
    /// every append/compaction, so it never drifts).
    pub overlay_nnz: AtomicU64,
    /// Background compactions that completed and swapped in a merged
    /// matrix.
    pub compactions: AtomicU64,
    /// Background compactions that failed (merge, encode, or artifact
    /// persist) — the old version stays servable. Stale builds discarded
    /// after losing a race with a concurrent append are not failures and
    /// are not counted.
    pub compaction_failures: AtomicU64,
    /// Iterative solve attempts through the service (converged, diverged
    /// **or** errored before iterating — so `solves` may exceed
    /// `solves_converged + solves_diverged` when requests fail on
    /// preconditions like a wrong-length right-hand side).
    pub solves: AtomicU64,
    /// Solves that reached their tolerance.
    pub solves_converged: AtomicU64,
    /// Solves that ran but stopped without converging (iteration cap or
    /// breakdown). Precondition/request errors count as `failed`, not
    /// here — divergence is a numerical signal, not an input bug.
    pub solves_diverged: AtomicU64,
    /// Gauge: per-block imbalance (slowest/mean block micros, ×1000) of
    /// the most recent timed engine call. 1000 = perfectly balanced.
    pub block_imbalance_milli: AtomicU64,
    /// Requests that received an adaptive-routing decision
    /// ([`crate::coordinator::adaptive::AdaptiveRouter::decide`]). With
    /// adaptation off (the default) this stays 0.
    pub routed_requests: AtomicU64,
    /// Routed requests that were epsilon-greedy exploration samples
    /// (served by a random non-incumbent arm). The conservation identity
    /// `explored + exploited == routed` holds on the router's own
    /// counters; this mirrors the explored side for exposition.
    pub explore_requests: AtomicU64,
    /// Hysteresis-confirmed route flips committed by the adaptive
    /// router. Each one also stamps a standalone
    /// [`Stage::Routed`] span.
    pub route_flips: AtomicU64,
    latencies_us: Mutex<LogHistogram>,
    cold_load_us: Mutex<LogHistogram>,
    solve_iters: Mutex<LogHistogram>,
    /// Queue wait (enqueue → dequeue), stamped by the dispatcher.
    queue_wait_us: Mutex<LogHistogram>,
    /// Mean block micros per timed engine call.
    block_mean_us: Mutex<LogHistogram>,
    /// Slowest block micros per timed engine call (straggler signal).
    block_max_us: Mutex<LogHistogram>,
    /// Per-format breakdown, keyed by the executing operator's
    /// `format_tag()` (`BTreeMap` so reports list formats in a stable
    /// order).
    per_format: Mutex<BTreeMap<&'static str, FormatStats>>,
    /// Per-tenant admission outcomes (only tenants named in
    /// `SubmitOptions` appear).
    tenants: Mutex<BTreeMap<String, TenantStats>>,
    /// Paper-headline gauges per dtANS-routed matrix, keyed by store id.
    paper: Mutex<BTreeMap<u64, PaperStats>>,
    /// Request-flow span collector (shared: everything that holds
    /// `Arc<Metrics>` can stamp stages).
    tracer: Tracer,
}

/// Per-format counters + latency histogram.
#[derive(Debug, Default)]
struct FormatStats {
    completed: u64,
    failed: u64,
    hist: LogHistogram,
}

/// Per-tenant admission counters.
#[derive(Debug, Default, Clone, Copy)]
struct TenantStats {
    admitted: u64,
    shed: u64,
}

/// Paper-headline gauges for one dtANS-routed matrix: compression ratio
/// fixed at registration, decode throughput updated per kernel run.
#[derive(Debug, Default, Clone)]
struct PaperStats {
    name: String,
    baseline_bytes: u64,
    encoded_bytes: u64,
    /// Latest observed decode throughput, stream bytes per second.
    decode_bps: u64,
    decode_samples: u64,
}

/// Snapshot of one format's request counters and latency quantiles (see
/// [`Metrics::format_summary`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct FormatSummary {
    /// Requests completed successfully on this format's kernel.
    pub completed: u64,
    /// Requests that failed while executing on this format's kernel.
    pub failed: u64,
    /// Latency quantiles over this format's full history.
    pub latency: LatencySummary,
}

/// Quantile summary of a latency histogram. `count` and `max_us` are
/// exact; the quantiles carry the histogram's ≤0.78% relative error.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    /// Number of samples (exact — histograms never window or subsample).
    pub count: usize,
    /// 50th percentile, microseconds.
    pub p50_us: u64,
    /// 90th percentile, microseconds.
    pub p90_us: u64,
    /// 99th percentile, microseconds.
    pub p99_us: u64,
    /// Maximum, microseconds (exact).
    pub max_us: u64,
}

impl LatencySummary {
    /// Summarize a histogram.
    fn from_hist(h: &LogHistogram) -> LatencySummary {
        LatencySummary {
            count: h.count() as usize,
            p50_us: h.quantile(0.50),
            p90_us: h.quantile(0.90),
            p99_us: h.quantile(0.99),
            max_us: h.max(),
        }
    }
}

/// Snapshot of the solver section (see [`Metrics::solver_summary`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolverSummary {
    /// Solves executed.
    pub solves: u64,
    /// Solves that converged.
    pub converged: u64,
    /// Solves that ran but did not converge (iteration cap or breakdown);
    /// errored solve requests appear in `solves` and the `failed`
    /// counter instead.
    pub diverged: u64,
    /// Solves with a recorded iteration count (`p50`/`p99`/`max` are
    /// iterations, not microseconds).
    pub iters_count: usize,
    /// Median iterations per solve.
    pub iters_p50: u64,
    /// 99th-percentile iterations per solve.
    pub iters_p99: u64,
    /// Maximum iterations per solve (exact).
    pub iters_max: u64,
}

/// Snapshot of one matrix's paper-headline gauges (see
/// [`Metrics::paper_summaries`]).
#[derive(Debug, Clone)]
pub struct PaperSummary {
    /// Store id of the matrix.
    pub id: u64,
    /// Registration name.
    pub name: String,
    /// Resident-CSR-equivalent bytes (the paper's baseline side).
    pub baseline_bytes: u64,
    /// Encoded dtANS container bytes.
    pub encoded_bytes: u64,
    /// Compression ratio, baseline / encoded (>1 = dtANS smaller).
    pub ratio: f64,
    /// Latest observed decode throughput, stream bytes per second.
    pub decode_bps: u64,
    /// Kernel runs that contributed a throughput observation.
    pub decode_samples: u64,
}

impl Metrics {
    /// Metrics with a configured tracer (sampling / capacity). `Default`
    /// uses [`ObsConfig::default`] — always-on tracing.
    pub fn with_obs(cfg: ObsConfig) -> Metrics {
        Metrics {
            tracer: Tracer::new(cfg),
            ..Default::default()
        }
    }

    /// The embedded request-flow span collector.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Record one request shed at admission. `quota` marks a per-tenant
    /// quota rejection (counted in both `shed` and `quota_rejected`).
    pub fn record_shed(&self, quota: bool) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if quota {
            self.quota_rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one request rejected at dispatch for an elapsed deadline.
    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the queue-depth gauge and its high-water mark.
    pub fn note_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_depth_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// Record one request's measured queue wait (enqueue → dequeue).
    pub fn record_queue_wait(&self, micros: u64) {
        self.queue_wait_us.lock().unwrap().record(micros);
    }

    /// Record one admission outcome against a named tenant.
    pub fn record_tenant(&self, tenant: &str, admitted: bool) {
        let mut t = self.tenants.lock().unwrap();
        let stats = t.entry(tenant.to_string()).or_default();
        if admitted {
            stats.admitted += 1;
        } else {
            stats.shed += 1;
        }
    }

    /// Per-tenant `(name, admitted, shed)` rows in stable order.
    pub fn tenant_counts(&self) -> Vec<(String, u64, u64)> {
        self.tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.admitted, v.shed))
            .collect()
    }

    /// Record one completed request's latency.
    pub fn record_latency(&self, micros: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().record(micros);
    }

    /// Record one completed request's latency against both the aggregate
    /// histogram and the executing format's own histogram.
    pub fn record_format_latency(&self, tag: &'static str, micros: u64) {
        self.record_latency(micros);
        let mut per = self.per_format.lock().unwrap();
        let stats = per.entry(tag).or_default();
        stats.completed += 1;
        stats.hist.record(micros);
    }

    /// Record one failed request against both the aggregate `failed`
    /// counter and the executing format's own counter.
    pub fn record_format_failure(&self, tag: &'static str) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.per_format.lock().unwrap().entry(tag).or_default().failed += 1;
    }

    /// Snapshot one format's counters and latency quantiles; `None` if no
    /// request has executed on that format.
    pub fn format_summary(&self, tag: &str) -> Option<FormatSummary> {
        let per = self.per_format.lock().unwrap();
        per.get(tag).map(|s| FormatSummary {
            completed: s.completed,
            failed: s.failed,
            latency: LatencySummary::from_hist(&s.hist),
        })
    }

    /// Tags that have recorded at least one request, in stable order.
    pub fn format_tags(&self) -> Vec<&'static str> {
        self.per_format.lock().unwrap().keys().copied().collect()
    }

    /// Record one whole iterative solve: its iteration count, outcome,
    /// and end-to-end latency. The solve is **one** submitted request and
    /// **one** latency sample in the aggregate and per-format histograms —
    /// never one per iteration (see the module docs for the p99-skew
    /// rationale).
    pub fn record_solve(&self, tag: &'static str, iterations: u64, converged: bool, micros: u64) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.solves.fetch_add(1, Ordering::Relaxed);
        if converged {
            self.solves_converged.fetch_add(1, Ordering::Relaxed);
        } else {
            self.solves_diverged.fetch_add(1, Ordering::Relaxed);
        }
        self.solve_iters.lock().unwrap().record(iterations);
        self.record_format_latency(tag, micros);
    }

    /// Record one errored solve (the request never produced an iterate —
    /// e.g. a dimension mismatch). Counted as a failed request and a
    /// solve attempt, but **not** as `solves_diverged`: that counter is
    /// reserved for solves that ran and did not converge.
    pub fn record_solve_failure(&self, tag: &'static str) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.solves.fetch_add(1, Ordering::Relaxed);
        self.record_format_failure(tag);
    }

    /// Snapshot the solver section: solve counts by outcome and
    /// iteration-count quantiles.
    pub fn solver_summary(&self) -> SolverSummary {
        let iters = LatencySummary::from_hist(&self.solve_iters.lock().unwrap());
        SolverSummary {
            solves: self.solves.load(Ordering::Relaxed),
            converged: self.solves_converged.load(Ordering::Relaxed),
            diverged: self.solves_diverged.load(Ordering::Relaxed),
            iters_count: iters.count,
            iters_p50: iters.p50_us,
            iters_p99: iters.p99_us,
            iters_max: iters.max_us,
        }
    }

    /// Record one cold load (store fault-in) latency for a known matrix:
    /// counter + histogram + a standalone [`Stage::ColdLoad`] span.
    pub fn record_cold_load_for(&self, id: u64, micros: u64) {
        self.cold_loads.fetch_add(1, Ordering::Relaxed);
        self.cold_load_us.lock().unwrap().record(micros);
        let span = self.tracer.begin();
        self.tracer.record(
            span,
            Stage::ColdLoad {
                matrix: id,
                dur_us: micros,
            },
        );
    }

    /// Record one committed adaptive route flip: bumps
    /// [`Metrics::route_flips`] and stamps a standalone
    /// [`Stage::Routed`] span (own trace id, terminal-free — the same
    /// pattern as cold loads and compactions, so the span-conservation
    /// oracle ignores it).
    pub fn record_route_flip(
        &self,
        matrix: u64,
        from: &'static str,
        to: &'static str,
        reason: &'static str,
    ) {
        self.route_flips.fetch_add(1, Ordering::Relaxed);
        let span = self.tracer.begin();
        self.tracer.record(span, Stage::Routed { matrix, from, to, reason });
    }

    /// Record one cold load without a matrix id (kept for callers that
    /// predate the tracing layer; the span carries id 0).
    pub fn record_cold_load(&self, micros: u64) {
        self.record_cold_load_for(0, micros);
    }

    /// Record one completed overlay compaction: counter + a standalone
    /// [`Stage::Compaction`] span (terminal-free, like cold loads — the
    /// span-conservation oracle must ignore it).
    pub fn record_compaction(&self, id: u64, micros: u64, nnz_absorbed: u64) {
        self.compactions.fetch_add(1, Ordering::Relaxed);
        let span = self.tracer.begin();
        self.tracer.record(
            span,
            Stage::Compaction {
                matrix: id,
                dur_us: micros,
                nnz_absorbed,
            },
        );
    }

    /// Record one timed engine call's per-block spread
    /// ([`SpmvEngine::run_timed`](crate::spmv::engine::SpmvEngine::run_timed)):
    /// mean and slowest-block micros go to histograms, and the
    /// slowest/mean ratio (×1000) becomes the imbalance gauge.
    pub fn record_block_timing(&self, _min_us: u64, max_us: u64, mean_us: u64) {
        self.block_mean_us.lock().unwrap().record(mean_us);
        self.block_max_us.lock().unwrap().record(max_us);
        let imb = max_us.saturating_mul(1000) / mean_us.max(1);
        self.block_imbalance_milli.store(imb.max(1000), Ordering::Relaxed);
    }

    /// Per-block imbalance of the most recent timed engine call:
    /// slowest / mean block micros (1.0 = perfectly balanced; 0.0 before
    /// any timed call).
    pub fn block_imbalance(&self) -> f64 {
        self.block_imbalance_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Record one matrix's compression sizes at registration (dtANS-routed
    /// matrices only — the paper's ratio is meaningless for CSR routes).
    pub fn record_compression(&self, id: u64, name: &str, baseline_bytes: u64, encoded_bytes: u64) {
        let mut p = self.paper.lock().unwrap();
        let stats = p.entry(id).or_default();
        stats.name = name.to_string();
        stats.baseline_bytes = baseline_bytes;
        stats.encoded_bytes = encoded_bytes;
    }

    /// Record one dtANS kernel run's decode throughput: `stream_bytes`
    /// decoded in `micros` microseconds.
    pub fn record_decode_rate(&self, id: u64, stream_bytes: u64, micros: u64) {
        let bps = stream_bytes.saturating_mul(1_000_000) / micros.max(1);
        let mut p = self.paper.lock().unwrap();
        let stats = p.entry(id).or_default();
        stats.decode_bps = bps;
        stats.decode_samples += 1;
    }

    /// Paper-headline gauges per dtANS-routed matrix, in store-id order.
    pub fn paper_summaries(&self) -> Vec<PaperSummary> {
        self.paper
            .lock()
            .unwrap()
            .iter()
            .map(|(&id, s)| PaperSummary {
                id,
                name: s.name.clone(),
                baseline_bytes: s.baseline_bytes,
                encoded_bytes: s.encoded_bytes,
                ratio: s.baseline_bytes as f64 / s.encoded_bytes.max(1) as f64,
                decode_bps: s.decode_bps,
                decode_samples: s.decode_samples,
            })
            .collect()
    }

    /// Quantile summary over all recorded request latencies.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary::from_hist(&self.latencies_us.lock().unwrap())
    }

    /// Quantile summary over all recorded cold-load latencies.
    pub fn cold_load_summary(&self) -> LatencySummary {
        LatencySummary::from_hist(&self.cold_load_us.lock().unwrap())
    }

    /// Quantile summary over all recorded queue waits.
    pub fn queue_wait_summary(&self) -> LatencySummary {
        LatencySummary::from_hist(&self.queue_wait_us.lock().unwrap())
    }

    /// Quantile summary of mean-block micros across timed engine calls.
    pub fn block_mean_summary(&self) -> LatencySummary {
        LatencySummary::from_hist(&self.block_mean_us.lock().unwrap())
    }

    /// Quantile summary of slowest-block micros across timed engine calls.
    pub fn block_max_summary(&self) -> LatencySummary {
        LatencySummary::from_hist(&self.block_max_us.lock().unwrap())
    }

    /// Clone of the aggregate request-latency histogram (for exporters).
    pub fn latency_histogram(&self) -> LogHistogram {
        self.latencies_us.lock().unwrap().clone()
    }

    /// Clone of the cold-load-latency histogram.
    pub fn cold_load_histogram(&self) -> LogHistogram {
        self.cold_load_us.lock().unwrap().clone()
    }

    /// Clone of the queue-wait histogram.
    pub fn queue_wait_histogram(&self) -> LogHistogram {
        self.queue_wait_us.lock().unwrap().clone()
    }

    /// Clone of the mean-block-micros histogram.
    pub fn block_mean_histogram(&self) -> LogHistogram {
        self.block_mean_us.lock().unwrap().clone()
    }

    /// Clone of the slowest-block-micros histogram.
    pub fn block_max_histogram(&self) -> LogHistogram {
        self.block_max_us.lock().unwrap().clone()
    }

    /// Clone of the solve-iteration-count histogram.
    pub fn solve_iters_histogram(&self) -> LogHistogram {
        self.solve_iters.lock().unwrap().clone()
    }

    /// Clone of one format's latency histogram, if it has served requests.
    pub fn format_histogram(&self, tag: &str) -> Option<LogHistogram> {
        self.per_format.lock().unwrap().get(tag).map(|s| s.hist.clone())
    }

    /// One-line human-readable report: the aggregate counters and
    /// quantiles (now including queue wait and, once any timed engine
    /// call ran, per-block imbalance), then a `solver:` section once any
    /// solve has run, one `fmt[tag]` section per format that has served
    /// requests, and one `paper[name]` section per dtANS-routed matrix.
    pub fn report(&self) -> String {
        let s = self.latency_summary();
        let c = self.cold_load_summary();
        let q = self.queue_wait_summary();
        let mut out = format!(
            "submitted={} completed={} failed={} shed={} expired={} batches={} \
             coalesced_batches={} coalesced_requests={} queue_depth={} queue_peak={} \
             p50={}µs p99={}µs max={}µs \
             store_hits={} store_misses={} evictions={} persist_failures={} cold_loads={} \
             acquires={} cold_p50={}µs cold_p99={}µs qwait_p50={}µs qwait_p99={}µs \
             deltas_appended={} overlay_nnz={} compactions={} compaction_failures={} \
             routed={} explored={} route_flips={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.coalesced_batches.load(Ordering::Relaxed),
            self.coalesced_requests.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
            self.queue_depth_peak.load(Ordering::Relaxed),
            s.p50_us,
            s.p99_us,
            s.max_us,
            self.store_hits.load(Ordering::Relaxed),
            self.store_misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
            self.persist_failures.load(Ordering::Relaxed),
            self.cold_loads.load(Ordering::Relaxed),
            self.acquires.load(Ordering::Relaxed),
            c.p50_us,
            c.p99_us,
            q.p50_us,
            q.p99_us,
            self.deltas_appended.load(Ordering::Relaxed),
            self.overlay_nnz.load(Ordering::Relaxed),
            self.compactions.load(Ordering::Relaxed),
            self.compaction_failures.load(Ordering::Relaxed),
            self.routed_requests.load(Ordering::Relaxed),
            self.explore_requests.load(Ordering::Relaxed),
            self.route_flips.load(Ordering::Relaxed),
        );
        let bm = self.block_max_summary();
        if bm.count > 0 {
            out.push_str(&format!(
                " blk_mean_p50={}µs blk_max_p99={}µs blk_imb={:.2}x",
                self.block_mean_summary().p50_us,
                bm.p99_us,
                self.block_imbalance(),
            ));
        }
        let sv = self.solver_summary();
        if sv.solves > 0 {
            out.push_str(&format!(
                " | solver: solves={} converged={} diverged={} iters_p50={} iters_p99={}",
                sv.solves, sv.converged, sv.diverged, sv.iters_p50, sv.iters_p99
            ));
        }
        let per = self.per_format.lock().unwrap();
        for (tag, stats) in per.iter() {
            let f = LatencySummary::from_hist(&stats.hist);
            out.push_str(&format!(
                " | fmt[{tag}]: ok={} fail={} p50={}µs p99={}µs",
                stats.completed, stats.failed, f.p50_us, f.p99_us
            ));
        }
        drop(per);
        for p in self.paper_summaries() {
            out.push_str(&format!(
                " | paper[{}]: ratio={:.2}x decode={:.1}MB/s",
                p.name,
                p.ratio,
                p.decode_bps as f64 / 1e6,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 100);
        assert!((49..=51).contains(&s.p50_us));
        assert!(s.p90_us >= 89);
        assert!(s.p99_us >= 98);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_summary() {
        let m = Metrics::default();
        assert_eq!(m.latency_summary().count, 0);
        assert!(m.report().contains("submitted=0"));
    }

    #[test]
    fn histogram_counts_every_sample_exactly() {
        // The pre-PR-7 rings windowed to the most recent 64k samples; the
        // histograms count everything with bounded quantile error.
        let m = Metrics::default();
        let n: u64 = 70_000;
        for i in 0..n {
            m.record_latency(i);
        }
        let s = m.latency_summary();
        assert_eq!(s.count as u64, n);
        assert_eq!(s.max_us, n - 1);
        let mid = n as f64 / 2.0;
        let rel = (s.p50_us as f64 - mid).abs() / mid;
        assert!(rel <= 0.02, "p50 {} vs exact {mid} (rel {rel})", s.p50_us);
        assert_eq!(m.completed.load(Ordering::Relaxed), n);
    }

    #[test]
    fn per_format_breakdown_is_independent_and_reported() {
        let m = Metrics::default();
        for i in 1..=50 {
            m.record_format_latency("csr", i);
        }
        for i in 100..=120 {
            m.record_format_latency("csr_dtans", i);
        }
        m.record_format_failure("csr_dtans");
        // Aggregate sees everything.
        assert_eq!(m.completed.load(Ordering::Relaxed), 71);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.latency_summary().count, 71);
        // Per-format histograms are disjoint.
        let csr = m.format_summary("csr").unwrap();
        assert_eq!((csr.completed, csr.failed), (50, 0));
        assert_eq!(csr.latency.count, 50);
        assert!(csr.latency.max_us <= 50);
        let dt = m.format_summary("csr_dtans").unwrap();
        assert_eq!((dt.completed, dt.failed), (21, 1));
        assert!(dt.latency.p50_us >= 100);
        assert!(m.format_summary("sell").is_none());
        assert_eq!(m.format_tags(), vec!["csr", "csr_dtans"]);
        let report = m.report();
        assert!(report.contains("fmt[csr]: ok=50 fail=0"), "{report}");
        assert!(report.contains("fmt[csr_dtans]: ok=21 fail=1"), "{report}");
    }

    #[test]
    fn solve_is_one_latency_sample_not_n() {
        let m = Metrics::default();
        // A 500-iteration solve on csr, one diverged solve on csr_dtans,
        // one errored solve (counts as failed + a solve attempt, NOT as
        // diverged — divergence is numerical, an error is an input bug).
        m.record_solve("csr", 500, true, 12_000);
        m.record_solve("csr_dtans", 42, false, 3_000);
        m.record_solve_failure("csr_dtans");
        let s = m.solver_summary();
        assert_eq!((s.solves, s.converged, s.diverged), (3, 1, 1));
        assert_eq!(s.iters_count, 2);
        assert_eq!(s.iters_max, 500);
        // The iteration counts must NOT have flooded the latency
        // histograms: one completed sample per successful solve, exactly.
        assert_eq!(m.latency_summary().count, 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 3);
        let csr = m.format_summary("csr").unwrap();
        assert_eq!((csr.completed, csr.latency.count), (1, 1));
        assert_eq!(csr.latency.max_us, 12_000);
        let report = m.report();
        assert!(report.contains("solver: solves=3 converged=1 diverged=1"), "{report}");
    }

    #[test]
    fn admission_counters_report_and_conserve() {
        let m = Metrics::default();
        // 7 submitted: 4 completed, 1 shed on depth, 1 shed on quota,
        // 1 expired at dispatch.
        for _ in 0..7 {
            m.submitted.fetch_add(1, Ordering::Relaxed);
        }
        for i in 0..4 {
            m.record_latency(10 + i);
        }
        m.record_shed(false);
        m.record_shed(true);
        m.record_expired();
        m.note_queue_depth(5);
        m.note_queue_depth(2);
        let (submitted, completed, failed, shed, expired) = (
            m.submitted.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed),
            m.failed.load(Ordering::Relaxed),
            m.shed.load(Ordering::Relaxed),
            m.expired.load(Ordering::Relaxed),
        );
        assert_eq!(completed + failed + shed + expired, submitted);
        assert_eq!(m.quota_rejected.load(Ordering::Relaxed), 1);
        // Gauge holds the latest value; the peak holds the maximum.
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 2);
        assert_eq!(m.queue_depth_peak.load(Ordering::Relaxed), 5);
        let report = m.report();
        assert!(report.contains("shed=2 expired=1"), "{report}");
        assert!(report.contains("queue_depth=2 queue_peak=5"), "{report}");
    }

    #[test]
    fn solver_section_absent_until_first_solve() {
        let m = Metrics::default();
        m.record_latency(5);
        assert!(!m.report().contains("solver:"));
        assert_eq!(m.solver_summary().solves, 0);
    }

    #[test]
    fn cold_load_histogram_is_independent() {
        let m = Metrics::default();
        m.record_latency(10);
        m.record_cold_load(5000);
        m.record_cold_load_for(3, 7000);
        assert_eq!(m.latency_summary().count, 1);
        let c = m.cold_load_summary();
        assert_eq!(c.count, 2);
        assert_eq!(c.max_us, 7000);
        assert_eq!(m.cold_loads.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("cold_loads=2"));
        // Cold loads also left standalone spans behind.
        let events = m.tracer().drain();
        assert_eq!(events.len(), 2);
        assert!(events
            .iter()
            .all(|e| matches!(e.stage, crate::obs::span::Stage::ColdLoad { .. })));
    }

    #[test]
    fn mutation_counters_reach_the_report_and_span_stream() {
        let m = Metrics::default();
        m.deltas_appended.fetch_add(5, Ordering::Relaxed);
        m.overlay_nnz.store(3, Ordering::Relaxed);
        m.record_compaction(7, 1200, 3);
        m.compaction_failures.fetch_add(1, Ordering::Relaxed);
        let report = m.report();
        assert!(report.contains("deltas_appended=5"), "{report}");
        assert!(report.contains("overlay_nnz=3"), "{report}");
        assert!(report.contains("compactions=1 compaction_failures=1"), "{report}");
        // The compaction left a standalone terminal-free span behind.
        let events = m.tracer().drain();
        assert_eq!(events.len(), 1);
        match events[0].stage {
            crate::obs::span::Stage::Compaction { matrix, dur_us, nnz_absorbed } => {
                assert_eq!((matrix, dur_us, nnz_absorbed), (7, 1200, 3));
            }
            ref s => panic!("expected a compaction span, got {s:?}"),
        }
        assert!(!events[0].stage.is_terminal());
    }

    #[test]
    fn queue_wait_and_block_timing_reach_the_report() {
        let m = Metrics::default();
        m.record_queue_wait(40);
        m.record_queue_wait(60);
        let q = m.queue_wait_summary();
        assert_eq!(q.count, 2);
        assert_eq!(q.max_us, 60);
        // Report shows queue wait even before any block timing...
        let report = m.report();
        assert!(report.contains("qwait_p50="), "{report}");
        assert!(!report.contains("blk_imb="), "{report}");
        // ...and the block section appears once a timed call lands.
        m.record_block_timing(80, 120, 100);
        assert!((m.block_imbalance() - 1.2).abs() < 1e-9);
        assert_eq!(m.block_max_summary().max_us, 120);
        assert_eq!(m.block_mean_summary().count, 1);
        assert!(m.report().contains("blk_imb=1.20x"), "{}", m.report());
    }

    #[test]
    fn tenant_counts_track_admission_outcomes() {
        let m = Metrics::default();
        m.record_tenant("acme", true);
        m.record_tenant("acme", true);
        m.record_tenant("acme", false);
        m.record_tenant("zeta", true);
        assert_eq!(
            m.tenant_counts(),
            vec![("acme".to_string(), 2, 1), ("zeta".to_string(), 1, 0)]
        );
    }

    #[test]
    fn paper_gauges_report_ratio_and_decode_rate() {
        let m = Metrics::default();
        m.record_compression(1, "web-graph", 3_000_000, 1_000_000);
        // 2 MB of stream decoded in 1000µs = 2 GB/s.
        m.record_decode_rate(1, 2_000_000, 1000);
        m.record_decode_rate(1, 2_000_000, 2000);
        let p = m.paper_summaries();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].name, "web-graph");
        assert!((p[0].ratio - 3.0).abs() < 1e-9);
        assert_eq!(p[0].decode_bps, 1_000_000_000);
        assert_eq!(p[0].decode_samples, 2);
        let report = m.report();
        assert!(report.contains("paper[web-graph]: ratio=3.00x"), "{report}");
    }

    #[test]
    fn with_obs_configures_the_tracer() {
        let m = Metrics::with_obs(ObsConfig {
            sample_one_in: 0,
            capacity: 8,
        });
        assert!(m.tracer().is_off());
        // Cold loads still count even with tracing off — only the span
        // is suppressed.
        m.record_cold_load_for(1, 100);
        assert_eq!(m.cold_loads.load(Ordering::Relaxed), 1);
        assert!(m.tracer().drain().is_empty());
    }
}
