//! Coordinate-list (COO) sparse matrix.

use crate::util::error::{DtansError, Result};

/// COO matrix: parallel arrays of (row, col, value) triplets.
///
/// Triplets need not be sorted; [`Coo::sorted_dedup`] canonicalizes
/// (row-major, duplicate entries summed) before conversion to CSR.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Coo {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row index per nonzero.
    pub rows: Vec<u32>,
    /// Column index per nonzero.
    pub cols: Vec<u32>,
    /// Value per nonzero.
    pub vals: Vec<f64>,
}

impl Coo {
    /// Empty matrix of given shape.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo {
            nrows,
            ncols,
            ..Default::default()
        }
    }

    /// Number of stored entries (before dedup these may repeat).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one triplet.
    #[inline]
    pub fn push(&mut self, r: u32, c: u32, v: f64) {
        self.rows.push(r);
        self.cols.push(c);
        self.vals.push(v);
    }

    /// Build from a triplet slice (the shape the delta-overlay append API
    /// and its tests speak), preserving arrival order.
    pub fn from_triplets(nrows: usize, ncols: usize, triplets: &[(u32, u32, f64)]) -> Coo {
        let mut out = Coo::new(nrows, ncols);
        for &(r, c, v) in triplets {
            out.push(r, c, v);
        }
        out
    }

    /// Validate indices are in range and arrays agree in length.
    pub fn validate(&self) -> Result<()> {
        if self.rows.len() != self.cols.len() || self.rows.len() != self.vals.len() {
            return Err(DtansError::InvalidMatrix("triplet arrays disagree in length".into()));
        }
        for (&r, &c) in self.rows.iter().zip(&self.cols) {
            if r as usize >= self.nrows || c as usize >= self.ncols {
                return Err(DtansError::InvalidMatrix(format!(
                    "entry ({r},{c}) out of bounds for {}x{}",
                    self.nrows, self.ncols
                )));
            }
        }
        Ok(())
    }

    /// Sort row-major (row, then col) and sum duplicates.
    pub fn sorted_dedup(&self) -> Coo {
        let mut idx: Vec<usize> = (0..self.nnz()).collect();
        idx.sort_unstable_by_key(|&i| ((self.rows[i] as u64) << 32) | self.cols[i] as u64);
        let mut out = Coo::new(self.nrows, self.ncols);
        for &i in &idx {
            let (r, c, v) = (self.rows[i], self.cols[i], self.vals[i]);
            if let (Some(&lr), Some(&lc)) = (out.rows.last(), out.cols.last()) {
                if lr == r && lc == c {
                    *out.vals.last_mut().unwrap() += v;
                    continue;
                }
            }
            out.push(r, c, v);
        }
        out
    }

    /// Dense row-major materialization (tests / tiny matrices only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for i in 0..self.nnz() {
            d[self.rows[i] as usize * self.ncols + self.cols[i] as usize] += self.vals[i];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_dedup_sums() {
        let mut m = Coo::new(2, 2);
        m.push(1, 1, 2.0);
        m.push(0, 0, 1.0);
        m.push(1, 1, 3.0);
        let s = m.sorted_dedup();
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.rows, vec![0, 1]);
        assert_eq!(s.vals, vec![1.0, 5.0]);
    }

    #[test]
    fn from_triplets_preserves_arrival_order() {
        let m = Coo::from_triplets(2, 2, &[(1, 0, 2.0), (0, 1, 1.0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.rows, vec![1, 0]);
        m.validate().unwrap();
    }

    #[test]
    fn validate_catches_oob() {
        let mut m = Coo::new(2, 2);
        m.push(2, 0, 1.0);
        assert!(m.validate().is_err());
    }

    #[test]
    fn dense_sums_duplicates() {
        let mut m = Coo::new(1, 2);
        m.push(0, 1, 1.5);
        m.push(0, 1, 0.5);
        assert_eq!(m.to_dense(), vec![0.0, 2.0]);
    }
}
