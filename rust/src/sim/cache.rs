//! Set-associative LRU cache model for the simulated L2.

/// Set-associative LRU cache tracking hit/miss bytes at line granularity.
#[derive(Debug, Clone)]
pub struct Cache {
    line: usize,
    sets: usize,
    ways: usize,
    /// tags\[set × ways + way\] (0 = empty; tag is line addr + 1).
    tags: Vec<u64>,
    /// LRU stamps parallel to `tags`.
    stamps: Vec<u64>,
    tick: u64,
    /// Bytes served from the cache.
    pub hit_bytes: u64,
    /// Bytes fetched from memory below.
    pub miss_bytes: u64,
}

impl Cache {
    /// New cache of `capacity` bytes with `line`-byte lines, `ways`-way.
    pub fn new(capacity: usize, line: usize, ways: usize) -> Cache {
        let lines = (capacity / line).max(1);
        let sets = (lines / ways).max(1);
        Cache {
            line,
            sets,
            ways,
            tags: vec![0; sets * ways],
            stamps: vec![0; sets * ways],
            tick: 0,
            hit_bytes: 0,
            miss_bytes: 0,
        }
    }

    /// Reset statistics but keep contents (for warm-cache second passes).
    pub fn reset_stats(&mut self) {
        self.hit_bytes = 0;
        self.miss_bytes = 0;
    }

    /// Flush contents and stats (cold cache).
    pub fn flush(&mut self) {
        self.tags.iter_mut().for_each(|t| *t = 0);
        self.reset_stats();
    }

    /// Access `len` bytes at `addr`; returns bytes that missed.
    pub fn access(&mut self, addr: u64, len: usize) -> usize {
        let mut missed = 0usize;
        let first = addr / self.line as u64;
        let last = (addr + len as u64 - 1) / self.line as u64;
        for line_addr in first..=last {
            self.tick += 1;
            // Simple multiplicative hash spreads strided bases over sets.
            let set = ((line_addr.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 32) as usize) % self.sets;
            let base = set * self.ways;
            let tag = line_addr + 1;
            let slots = &mut self.tags[base..base + self.ways];
            if let Some(w) = slots.iter().position(|&t| t == tag) {
                self.stamps[base + w] = self.tick;
                self.hit_bytes += self.line as u64;
            } else {
                // Evict LRU way.
                let (w, _) = self.stamps[base..base + self.ways]
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &s)| s)
                    .unwrap();
                self.tags[base + w] = tag;
                self.stamps[base + w] = self.tick;
                self.miss_bytes += self.line as u64;
                missed += self.line;
            }
        }
        missed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_access_hits() {
        let mut c = Cache::new(1 << 16, 128, 4);
        c.access(0, 128);
        assert_eq!(c.miss_bytes, 128);
        c.access(0, 128);
        assert_eq!(c.hit_bytes, 128);
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = Cache::new(1 << 14, 128, 4); // 16 KB
        // Stream 1 MB twice: second pass still mostly misses.
        for pass in 0..2 {
            if pass == 1 {
                c.reset_stats();
            }
            for i in 0..8192u64 {
                c.access(i * 128, 128);
            }
        }
        assert!(c.miss_bytes > c.hit_bytes * 4);
    }

    #[test]
    fn working_set_smaller_than_cache_warm_hits() {
        let mut c = Cache::new(1 << 20, 128, 16); // 1 MB
        for i in 0..1024u64 {
            c.access(i * 128, 128);
        }
        c.reset_stats();
        for i in 0..1024u64 {
            c.access(i * 128, 128);
        }
        assert_eq!(c.miss_bytes, 0);
    }

    #[test]
    fn flush_clears() {
        let mut c = Cache::new(1 << 16, 128, 4);
        c.access(0, 128);
        c.flush();
        c.access(0, 128);
        assert_eq!(c.miss_bytes, 128);
    }
}
